"""Config-driven multi-leg experiment orchestration.

One :class:`ExperimentSpec` — loaded from a JSON or TOML file, or
synthesized from the legacy ``benchmarks/run.py`` flags — names every leg
of a benchmark campaign: which section runs, with which parameters, swept
over which axes.  This replaces the hand-rolled ``--sections`` dispatch
(the serverless-benchmarks idiom: the *config file* is the experiment, the
runner just executes it), so a sweep over sections × engine × K × D ×
source is one committed config instead of a shell loop.

Config shape (JSON; TOML maps 1:1)::

    {
      "name": "ci-smoke",
      "defaults": {"smoke": true},          // merged under every leg
      "legs": [
        {"section": "scaling", "params": {"k_values": [1, 8], "groups": 5,
                                          "device_sweep": false}},
        {"section": "serve",
         "matrix": {"k_values": [[1], [1, 8]]}}   // one leg per combo
      ]
    }

``matrix`` axes cross-multiply: every combination becomes its own leg with
the axis values merged over ``params``.  Leg params are validated against
the target section's ``main()`` signature before anything runs, so a typo
fails the whole campaign upfront, not after an hour of sweeps.

Run with ``python -m benchmarks.run --experiment <config>`` (each leg's
``BENCH_<section>.json`` lands in ``--json-dir``, on the reporting schema,
covered by the trend gate automatically).
"""
from __future__ import annotations

import dataclasses
import inspect
import itertools
import json
import os
from typing import Any, Callable, Dict, List, Mapping, Optional, Sequence, Tuple

#: the benchmark sections (authoritative; benchmarks/run.py re-exports)
SECTIONS = (
    "hier", "kernels", "embed", "scaling", "cascade_kernel", "serve", "fleet",
    "query", "obs",
)

_SECTION_MODULES = {
    "hier": "benchmarks.bench_hier_update",
    "kernels": "benchmarks.bench_kernels",
    "embed": "benchmarks.bench_embed_grad",
    "scaling": "benchmarks.bench_scaling",
    "cascade_kernel": "benchmarks.bench_cascade_kernel",
    "serve": "benchmarks.bench_serve",
    "fleet": "benchmarks.bench_fleet",
    "query": "benchmarks.bench_query",
    "obs": "benchmarks.bench_obs",
}


class ExperimentError(ValueError):
    """An experiment config is malformed or names unknown sections/params."""


def _load_toml(path: str) -> Dict[str, Any]:
    try:
        import tomllib as toml_mod  # Python >= 3.11
    except ModuleNotFoundError:
        try:
            import tomli as toml_mod  # type: ignore[no-redef]
        except ModuleNotFoundError:
            raise ExperimentError(
                f"{path}: TOML configs need tomllib (Python 3.11+) or the "
                f"optional 'tomli' package; neither is available — use the "
                f"JSON config format instead"
            ) from None
    with open(path, "rb") as f:
        return toml_mod.load(f)


@dataclasses.dataclass(frozen=True)
class ExperimentLeg:
    """One benchmark invocation: a section plus the kwargs for its main."""

    section: str
    params: Tuple[Tuple[str, Any], ...] = ()
    name: str = ""

    @property
    def label(self) -> str:
        return self.name or self.section

    def kwargs(self) -> Dict[str, Any]:
        return dict(self.params)

    def validate(self) -> "ExperimentLeg":
        if self.section not in SECTIONS:
            raise ExperimentError(
                f"leg {self.label!r}: unknown section {self.section!r}; "
                f"known: {list(SECTIONS)}"
            )
        return self


def _freeze_params(params: Mapping[str, Any]) -> Tuple[Tuple[str, Any], ...]:
    def freeze(v: Any) -> Any:
        if isinstance(v, list):
            return tuple(freeze(x) for x in v)
        return v

    return tuple(sorted((str(k), freeze(v)) for k, v in params.items()))


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """A named, validated set of experiment legs."""

    name: str
    legs: Tuple[ExperimentLeg, ...]
    json_dir: Optional[str] = None
    source: str = ""  # config path or synthesis origin (diagnostics)

    def validate(self) -> "ExperimentSpec":
        if not self.name:
            raise ExperimentError("experiment spec needs a non-empty name")
        if not self.legs:
            raise ExperimentError(f"experiment {self.name!r} has no legs")
        for leg in self.legs:
            leg.validate()
        return self

    def sections(self) -> Tuple[str, ...]:
        return tuple(sorted({leg.section for leg in self.legs}))

    # -- construction --------------------------------------------------------
    @classmethod
    def from_dict(
        cls, payload: Mapping[str, Any], source: str = ""
    ) -> "ExperimentSpec":
        if not isinstance(payload, Mapping):
            raise ExperimentError(
                f"{source or 'config'}: experiment config must be a mapping"
            )
        unknown = set(payload) - {"name", "defaults", "legs", "json_dir"}
        if unknown:
            raise ExperimentError(
                f"{source or 'config'}: unknown top-level keys {sorted(unknown)}"
            )
        defaults = dict(payload.get("defaults") or {})
        raw_legs = payload.get("legs")
        if not isinstance(raw_legs, list) or not raw_legs:
            raise ExperimentError(
                f"{source or 'config'}: 'legs' must be a non-empty list"
            )
        legs: List[ExperimentLeg] = []
        for i, raw in enumerate(raw_legs):
            if not isinstance(raw, Mapping):
                raise ExperimentError(
                    f"{source or 'config'}: leg #{i} must be a mapping"
                )
            bad = set(raw) - {"section", "name", "params", "matrix"}
            if bad:
                raise ExperimentError(
                    f"{source or 'config'}: leg #{i} has unknown keys "
                    f"{sorted(bad)}"
                )
            section = raw.get("section")
            base = {**defaults, **dict(raw.get("params") or {})}
            matrix = dict(raw.get("matrix") or {})
            for axis, values in matrix.items():
                if not isinstance(values, list) or not values:
                    raise ExperimentError(
                        f"{source or 'config'}: leg #{i} matrix axis "
                        f"{axis!r} must be a non-empty list"
                    )
            combos = (
                [dict(zip(matrix, combo))
                 for combo in itertools.product(*matrix.values())]
                if matrix
                else [{}]
            )
            for combo in combos:
                suffix = "".join(
                    f",{k}={v}" for k, v in sorted(combo.items())
                )
                name = raw.get("name") or section or f"leg{i}"
                legs.append(
                    ExperimentLeg(
                        section=section,
                        params=_freeze_params({**base, **combo}),
                        name=f"{name}{suffix}" if suffix else name,
                    )
                )
        return cls(
            name=payload.get("name") or os.path.basename(source) or "experiment",
            legs=tuple(legs),
            json_dir=payload.get("json_dir"),
            source=source,
        ).validate()

    @classmethod
    def from_file(cls, path: str) -> "ExperimentSpec":
        if path.endswith(".toml"):
            payload = _load_toml(path)
        else:
            try:
                with open(path) as f:
                    payload = json.load(f)
            except (OSError, json.JSONDecodeError) as e:
                raise ExperimentError(f"{path}: unreadable config ({e})") from None
        return cls.from_dict(payload, source=path)

    @classmethod
    def from_legacy(
        cls,
        sections: Sequence[str],
        smoke: bool = False,
        full: bool = False,
        json_dir: Optional[str] = None,
    ) -> "ExperimentSpec":
        """Synthesize the spec the legacy ``--section/--sections/--smoke/
        --full`` flags used to dispatch by hand (parameter values preserved
        exactly, so archived trajectories stay comparable)."""
        legs: List[ExperimentLeg] = []
        for section in sections:
            if section not in SECTIONS:
                raise ExperimentError(
                    f"unknown section(s) ['{section}']; known: {list(SECTIONS)}"
                )
            params: Dict[str, Any] = {}
            if section == "hier":
                if full:
                    params = {"total_edges": 100_000_000,
                              "group_size": 100_000, "scale": 26}
                elif smoke:
                    params = {"total_edges": 80_000, "group_size": 2_000,
                              "scale": 14}
            elif section == "scaling":
                if smoke:
                    params = {"k_values": (1, 8), "groups": 5,
                              "device_sweep": False}
            else:  # kernels/embed/cascade_kernel/serve/fleet/query take smoke=
                params = {"smoke": bool(smoke)}
            legs.append(
                ExperimentLeg(section=section, params=_freeze_params(params))
            )
        mode = "full" if full else ("smoke" if smoke else "default")
        return cls(
            name=f"legacy-{mode}",
            legs=tuple(legs),
            json_dir=json_dir,
            source="legacy-flags",
        ).validate()


def _section_main(section: str) -> Callable:
    import importlib

    try:
        mod = importlib.import_module(_SECTION_MODULES[section])
    except ImportError as e:
        raise ExperimentError(
            f"section {section!r}: cannot import {_SECTION_MODULES[section]} "
            f"(run from the repo root so the 'benchmarks' package is on the "
            f"path): {e}"
        ) from None
    return mod.main


def validate_leg_params(leg: ExperimentLeg) -> None:
    """Check the leg's params against the section main's real signature —
    a typo'd axis fails the campaign before any leg runs."""
    sig = inspect.signature(_section_main(leg.section))
    unknown = set(leg.kwargs()) - set(sig.parameters)
    if unknown:
        raise ExperimentError(
            f"leg {leg.label!r}: section {leg.section!r} does not accept "
            f"{sorted(unknown)}; accepted: {sorted(sig.parameters)}"
        )


def run_spec(
    spec: ExperimentSpec, json_dir: Optional[str] = None
) -> List[Tuple[ExperimentLeg, Any]]:
    """Execute every leg in order; returns ``[(leg, main() result)]``.

    Each section writes its ``BENCH_<section>.json`` into ``json_dir`` (or
    the spec's, or ``$BENCH_JSON_DIR``) via the reporting layer, exactly as
    the legacy dispatch did — the artifact contract is unchanged.
    """
    spec.validate()
    out_dir = json_dir or spec.json_dir
    if out_dir:
        os.environ["BENCH_JSON_DIR"] = out_dir
    for leg in spec.legs:  # validate everything before running anything
        validate_leg_params(leg)
    results: List[Tuple[ExperimentLeg, Any]] = []
    for leg in spec.legs:
        print(
            f"experiment,{spec.name},leg={leg.label},section={leg.section},"
            + ",".join(f"{k}={v}" for k, v in leg.params),
            flush=True,
        )
        results.append((leg, _section_main(leg.section)(**leg.kwargs())))
    return results
