"""Sweep multi-leg CI artifact trees into one normalized run record.

A CI run scatters ``BENCH_<section>.json`` files across matrix legs —
``benchmark-json-d1/``, ``benchmark-json-d8/``, ``benchmark-json-serve/``
(or one flat ``bench-artifacts/`` directory for a single local run).
:func:`sweep_section_runs` walks the tree and validates every payload into
a :class:`~repro.bench.models.SectionRun`; :func:`normalize_run` folds them
into one :class:`~repro.bench.models.RunRecord` — the unit the history
file, the trend gate, and the report generator all speak.

Legs are labelled from the payload itself (``d<device_count>`` from the
recorded host info), not from directory names: artifacts self-describe, so
a renamed download directory can't silently fork a measurement's history.
When the same (section, leg, name, params) key appears twice in one sweep
(e.g. the serve section runs both in the serve-smoke job and the d1 bench
leg), the later-timestamped artifact wins — re-runs overwrite, never
duplicate.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Iterable, List, Optional, Tuple

from .models import (
    ModelError,
    NormalizedMeasurement,
    RunRecord,
    SectionRun,
)


def find_bench_files(root: str) -> List[str]:
    """Every ``BENCH_*.json`` under ``root`` (recursive, sorted).

    ``BENCH_report.json`` is the *output* of the report generator, not a
    section artifact — it is never swept back in.
    """
    out: List[str] = []
    for dirpath, _dirnames, filenames in os.walk(root):
        for name in sorted(filenames):
            if (
                name.startswith("BENCH_")
                and name.endswith(".json")
                and name != "BENCH_report.json"
            ):
                out.append(os.path.join(dirpath, name))
    return sorted(out)


def parse_section_file(path: str) -> SectionRun:
    """Parse + validate one ``BENCH_<section>.json`` file."""
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise ModelError(f"{path}: unreadable BENCH payload ({e})") from None
    return SectionRun.from_payload(payload, source_path=path)


def sweep_section_runs(
    root: str, strict: bool = True
) -> Tuple[List[SectionRun], List[str]]:
    """Parse every artifact under ``root``.

    Returns ``(runs, problems)``.  ``strict=True`` raises on the first
    malformed payload (the history appender must never ingest garbage);
    ``strict=False`` collects human-readable problem strings instead (the
    gate tolerates a torn artifact the same way the legacy gate did).
    """
    runs: List[SectionRun] = []
    problems: List[str] = []
    for path in find_bench_files(root):
        try:
            runs.append(parse_section_file(path))
        except ModelError as e:
            if strict:
                raise
            problems.append(str(e))
    return runs, problems


def leg_label(run: SectionRun) -> str:
    """The matrix-leg label of one artifact: ``d<device_count>`` when the
    payload recorded its host, else ''."""
    n = run.device_count
    return f"d{n}" if n is not None else ""


def normalize_run(
    section_runs: Iterable[SectionRun],
    run_id: Optional[str] = None,
) -> RunRecord:
    """Fold validated section artifacts into one :class:`RunRecord`.

    Provenance (commit, branch, jax version, backend) is taken from the
    artifacts themselves — first non-unknown value wins; the run window is
    the min/max of the per-section timestamps.  ``run_id`` defaults to the
    artifacts' ``ci_run_id`` and falls back to ``local-<commit>``.
    """
    section_runs = list(section_runs)
    if not section_runs:
        raise ModelError("normalize_run: no section artifacts to normalize")

    def first(values: Iterable[Optional[str]], default: str) -> str:
        for v in values:
            if v and v != "unknown":
                return v
        return default

    commit = first((r.git_commit_hash for r in section_runs), "unknown")
    branch = first((r.git_branch for r in section_runs), "unknown")
    jax_version = first((r.jax_version for r in section_runs), "") or None
    backend = first((r.backend for r in section_runs), "") or None
    if run_id is None:
        run_id = first((r.ci_run_id for r in section_runs), "") or (
            f"local-{commit[:12]}"
        )
    starts = sorted(r.run_start_ts for r in section_runs if r.run_start_ts)
    ends = sorted(r.run_end_ts for r in section_runs if r.run_end_ts)

    # later-timestamped artifact wins a key collision (re-runs overwrite)
    ordered = sorted(section_runs, key=lambda r: (r.run_start_ts, r.source_path))
    merged: Dict[Tuple, NormalizedMeasurement] = {}
    for run in ordered:
        leg = leg_label(run)
        for m in run.measurements:
            nm = NormalizedMeasurement(
                section=run.section,
                leg=leg,
                name=m.name,
                params=dict(m.params),
                updates_per_sec=m.updates_per_sec,
                wall_s=m.wall_s,
                passed=m.passed,
                extras=dict(m.extras),
            ).validate()
            merged[nm.key()] = nm

    return RunRecord(
        run_id=str(run_id),
        git_commit_hash=commit,
        git_branch=branch,
        run_start_ts=starts[0] if starts else "",
        run_end_ts=ends[-1] if ends else "",
        jax_version=jax_version,
        backend=backend,
        measurements=[merged[k] for k in sorted(merged)],
    ).validate()


def normalize_dir(
    root: str, run_id: Optional[str] = None, strict: bool = True
) -> Tuple[RunRecord, List[str]]:
    """``sweep_section_runs`` + ``normalize_run`` in one call.

    Returns ``(record, problems)``; raises :class:`ModelError` when the
    tree holds no parseable artifact at all.
    """
    runs, problems = sweep_section_runs(root, strict=strict)
    if not runs:
        raise ModelError(
            f"no BENCH_*.json artifacts under {root}"
            + (f" ({len(problems)} unreadable)" if problems else "")
        )
    return normalize_run(runs, run_id=run_id), problems
