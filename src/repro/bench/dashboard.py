"""Static perf dashboard over the cross-run report.

Renders ``BENCH_report.json`` (the machine-readable rate series
``repro.bench.report`` emits — the ROADMAP's named dashboard input) into
one self-contained HTML file: an inline-SVG sparkline per measurement
series, latest/median/best columns, and a marker on every run where the
recorded ``jax_version`` changed (toolchain bumps are the usual suspect
behind an otherwise unexplained rate step).

No external assets, no JavaScript frameworks — the file is an artifact
the perf-history CI job uploads next to the report, viewable offline.

Usage::

    python -m repro.bench.dashboard --report report/BENCH_report.json \
        --out report/dashboard.html
    python -m repro.bench.dashboard --history perf_history.jsonl \
        --out dashboard.html          # build the payload in-process
"""
from __future__ import annotations

import argparse
import html
import json
import os
from typing import Any, Dict, List, Optional

SPARK_W, SPARK_H = 220, 36
_PAD = 3  # sparkline inner padding, px


def _fmt_rate(rate: float) -> str:
    return f"{rate:,.0f}"


def _spark_points(rates: List[float]) -> List[tuple]:
    """(x, y) pixel coordinates, y normalized over the series range."""
    lo, hi = min(rates), max(rates)
    span = (hi - lo) or 1.0
    n = len(rates)
    xs = (
        [SPARK_W / 2.0]
        if n == 1
        else [_PAD + i * (SPARK_W - 2 * _PAD) / (n - 1) for i in range(n)]
    )
    ys = [
        SPARK_H - _PAD - (r - lo) / span * (SPARK_H - 2 * _PAD) for r in rates
    ]
    return list(zip(xs, ys))


def _sparkline(points: List[Dict[str, Any]]) -> str:
    """Inline SVG: the rate polyline plus a marker wherever jax_version
    changed from the previous run (hover shows the new version)."""
    rates = [float(p["updates_per_sec"]) for p in points]
    coords = _spark_points(rates)
    poly = " ".join(f"{x:.1f},{y:.1f}" for x, y in coords)
    parts = [
        f'<svg width="{SPARK_W}" height="{SPARK_H}" '
        f'viewBox="0 0 {SPARK_W} {SPARK_H}" class="spark">',
        f'<polyline points="{poly}" fill="none" stroke="#2c7fb8" '
        f'stroke-width="1.5"/>',
    ]
    prev_jax: Optional[str] = None
    for (x, y), p in zip(coords, points):
        jax_v = p.get("jax_version")
        if jax_v is not None and prev_jax is not None and jax_v != prev_jax:
            label = html.escape(f"jax {prev_jax} -> {jax_v}")
            parts.append(
                f'<circle cx="{x:.1f}" cy="{y:.1f}" r="3" fill="#d95f0e">'
                f"<title>{label}</title></circle>"
            )
        if jax_v is not None:
            prev_jax = jax_v
    # terminal dot: where the series stands now
    x, y = coords[-1]
    parts.append(f'<circle cx="{x:.1f}" cy="{y:.1f}" r="2" fill="#2c7fb8"/>')
    parts.append("</svg>")
    return "".join(parts)


def _series_label(s: Dict[str, Any]) -> str:
    label = f"{s['section']}/{s['name']}"
    if s.get("leg"):
        label += f"@{s['leg']}"
    return label


def _series_row(s: Dict[str, Any]) -> str:
    points = s["points"]
    rates = [float(p["updates_per_sec"]) for p in points]
    latest = rates[-1]
    median = float(s["median_updates_per_sec"])
    delta = (latest - median) / median if median > 0 else 0.0
    cls = "up" if delta >= 0 else ("down" if delta < -0.10 else "flat")
    params = ",".join(
        f"{k}={v}" for k, v in sorted(s.get("params", {}).items())[:3]
    )
    return (
        "<tr>"
        f"<td class=\"name\">{html.escape(_series_label(s))}"
        f"<div class=\"params\">{html.escape(params)}</div></td>"
        f"<td>{html.escape(str(s.get('engine', '-')))}</td>"
        f"<td class=\"num\">{s.get('k', 1)}</td>"
        f"<td class=\"num\">{s.get('d', 1)}</td>"
        f"<td>{html.escape(str(s.get('source', '-')))}</td>"
        f"<td>{_sparkline(points)}</td>"
        f"<td class=\"num\">{len(points)}</td>"
        f"<td class=\"num\">{_fmt_rate(latest)}</td>"
        f"<td class=\"num\">{_fmt_rate(median)}</td>"
        f"<td class=\"num\">{_fmt_rate(max(rates))}</td>"
        f"<td class=\"num {cls}\">{delta:+.1%}</td>"
        "</tr>"
    )


_STYLE = """
body { font: 13px/1.45 system-ui, sans-serif; margin: 24px; color: #222; }
h1 { font-size: 18px; } .meta { color: #666; margin-bottom: 16px; }
table { border-collapse: collapse; width: 100%; }
th, td { padding: 4px 8px; border-bottom: 1px solid #e5e5e5;
         text-align: left; vertical-align: middle; }
th { border-bottom: 2px solid #bbb; position: sticky; top: 0;
     background: #fff; }
td.num, th.num { text-align: right; font-variant-numeric: tabular-nums; }
td.name { font-weight: 600; }
.params { font-weight: 400; color: #888; font-size: 11px; }
.up { color: #1a7f37; } .down { color: #b42318; } .flat { color: #666; }
.spark { display: block; }
.legend { margin-top: 12px; color: #666; font-size: 12px; }
.legend .dot { color: #d95f0e; }
"""


def render_dashboard(payload: Dict[str, Any]) -> str:
    series = payload.get("series", [])
    rows = "\n".join(_series_row(s) for s in series)
    if not rows:
        rows = ("<tr><td colspan=\"11\">no rate measurements in the "
                "report</td></tr>")
    return f"""<!doctype html>
<html lang="en"><head><meta charset="utf-8">
<title>repro perf dashboard</title>
<style>{_STYLE}</style></head>
<body>
<h1>Benchmark rate trajectory</h1>
<div class="meta">{payload.get('n_runs', 0)} run(s) in history;
rolling window {payload.get('window', 5)};
{len(series)} measurement series.</div>
<table>
<thead><tr><th>measurement</th><th>engine</th><th class="num">K</th>
<th class="num">D</th><th>source</th><th>trend</th>
<th class="num">runs</th><th class="num">latest /s</th>
<th class="num">median /s</th><th class="num">best /s</th>
<th class="num">vs median</th></tr></thead>
<tbody>
{rows}
</tbody>
</table>
<div class="legend"><span class="dot">&#9679;</span> jax version changed
on that run (hover for old &rarr; new); blue dot marks the latest run.</div>
</body></html>
"""


def write_dashboard(payload: Dict[str, Any], out_path: str) -> str:
    out_dir = os.path.dirname(out_path)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    with open(out_path, "w") as f:
        f.write(render_dashboard(payload))
    return out_path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench.dashboard",
        description="static HTML dashboard over BENCH_report.json",
    )
    src = ap.add_mutually_exclusive_group()
    src.add_argument("--report", default=None,
                     help="BENCH_report.json from repro.bench.report")
    src.add_argument("--history", default=None,
                     help="perf-history JSONL (payload built in-process)")
    ap.add_argument("--out", default="dashboard.html")
    ap.add_argument("--window", type=int, default=5)
    args = ap.parse_args(argv)

    if args.report is not None:
        with open(args.report) as f:
            payload = json.load(f)
    else:
        from .history import default_history_path, load_history
        from .report import report_payload

        history_path = args.history or default_history_path()
        runs, problems = load_history(history_path)
        for p in problems:
            print(f"dashboard,unreadable,{p}")
        if not runs:
            print(f"dashboard,error,no runs in {history_path}")
            return 1
        payload = report_payload(runs, window=args.window)

    path = write_dashboard(payload, args.out)
    print(
        f"dashboard,written,series={len(payload.get('series', []))},"
        f"html={path}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
