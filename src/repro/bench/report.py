"""Cross-run perf report: updates/s per engine × K × D × source.

Reads the committed perf history (plus, optionally, a fresh artifact tree
not yet appended) and emits:

* ``BENCH_report.json`` — machine-readable rate series: one entry per
  (engine, K, D, source, section, name, leg, params) measurement key, with
  one point per run across the repo's life (the input for a dashboard —
  the ROADMAP's named follow-on);
* ``BENCH_report.md`` — the human summary table: latest rate vs the
  rolling median, per series.

The dimension columns are derived from each measurement's own params
(``engine`` / ``k_per_device`` / ``n_devices``) with documented per-section
fallbacks where a bench predates the dimension (e.g. the serve bench's
engine is the session's auto pick: ``single`` at K=1, ``packed`` at K>1 on
CPU hosts).

Usage::

    python -m repro.bench.report [--history perf_history.jsonl] \
        [--fresh bench-artifacts] [--out report-dir] [--window 5]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import statistics
import sys
from typing import Any, Dict, List, Optional, Tuple

from .history import default_history_path, load_history
from .models import NormalizedMeasurement, RunRecord
from .parsers import normalize_dir

REPORT_SCHEMA_VERSION = 1

#: engine fallback when a measurement's params don't carry one
_SECTION_ENGINE = {
    "hier_update": "single",
    "scaling": "mesh",
    "embed_grad": "single",
    "kernels": "kernel-ref",
}

#: source fallback per section (what traffic fed the measurement)
_SECTION_SOURCE = {
    "hier_update": "rmat",
    "scaling": "rmat",
    "cascade_kernel": "synthetic",
    "kernels": "synthetic",
    "embed_grad": "tokens",
}

#: serve measurements name their ingress path, not a params field
_SERVE_SOURCE = {
    "raw_engine_rate": "preroute",
    "served_rate": "array",
    "socket_rate": "tcp",
}


def measurement_dims(m: NormalizedMeasurement) -> Dict[str, Any]:
    """The (engine, k, d, source) axes of one measurement."""
    p = m.params
    k = p.get("k_per_device", p.get("k", 1))
    d = p.get("n_devices")
    if d is None and m.leg.startswith("d") and m.leg[1:].isdigit():
        d = int(m.leg[1:])
    engine = p.get("engine")
    if engine is None:
        if m.section == "serve":
            engine = "single" if int(k) == 1 else "packed"
        else:
            engine = _SECTION_ENGINE.get(m.section, "-")
    source = p.get("source")
    if source is None:
        if m.section == "serve":
            source = _SERVE_SOURCE.get(m.name, "array")
        else:
            source = _SECTION_SOURCE.get(m.section, "-")
    return {
        "engine": str(engine),
        "k": int(k),
        "d": int(d) if d is not None else 1,
        "source": str(source),
    }


@dataclasses.dataclass
class RateSeries:
    """One measurement key's rate trajectory across runs."""

    section: str
    name: str
    leg: str
    dims: Dict[str, Any]
    params: Dict[str, Any]
    points: List[Dict[str, Any]] = dataclasses.field(default_factory=list)

    def latest(self) -> float:
        return self.points[-1]["updates_per_sec"]

    def median(self, window: int = 5) -> float:
        rates = [p["updates_per_sec"] for p in self.points[-window:]]
        return statistics.median(rates)

    def to_json(self, window: int = 5) -> Dict[str, Any]:
        return {
            "section": self.section,
            "name": self.name,
            "leg": self.leg,
            **self.dims,
            "params": self.params,
            "n_runs": len(self.points),
            "latest_updates_per_sec": self.latest(),
            "median_updates_per_sec": self.median(window),
            "best_updates_per_sec": max(
                p["updates_per_sec"] for p in self.points
            ),
            "points": self.points,
        }


def build_series(
    runs: List[RunRecord],
) -> List[RateSeries]:
    """Group every rate-carrying measurement across runs (oldest-first)."""
    series: Dict[Tuple, RateSeries] = {}
    for run in runs:
        for m in run.measurements:
            if m.updates_per_sec is None:
                continue
            key = m.key()
            if key not in series:
                series[key] = RateSeries(
                    section=m.section,
                    name=m.name,
                    leg=m.leg,
                    dims=measurement_dims(m),
                    params=dict(m.params),
                )
            series[key].points.append(
                {
                    "run_id": run.run_id,
                    "git_commit_hash": run.git_commit_hash,
                    "run_end_ts": run.run_end_ts,
                    "jax_version": run.jax_version,
                    "updates_per_sec": m.updates_per_sec,
                }
            )
    return [series[k] for k in sorted(series)]


def report_payload(
    runs: List[RunRecord], window: int = 5
) -> Dict[str, Any]:
    all_series = build_series(runs)
    return {
        "schema_version": REPORT_SCHEMA_VERSION,
        "n_runs": len(runs),
        "run_ids": [r.run_id for r in runs],
        "window": window,
        "series": [s.to_json(window) for s in all_series],
    }


def _fmt_rate(rate: float) -> str:
    return f"{rate:,.0f}"


def report_markdown(runs: List[RunRecord], window: int = 5) -> str:
    """The human-readable trajectory table."""
    all_series = build_series(runs)
    lines = [
        "# Benchmark rate trajectory",
        "",
        f"{len(runs)} run(s) in history; rolling window {window}.",
        "",
        "| measurement | engine | K | D | source | runs | first | latest "
        "| vs median |",
        "|---|---|---:|---:|---|---:|---:|---:|---:|",
    ]
    for s in all_series:
        label = f"{s.section}/{s.name}" + (f"@{s.leg}" if s.leg else "")
        short = ",".join(
            f"{k}={v}" for k, v in sorted(s.params.items())[:2]
        )
        if short:
            label += f" [{short}]"
        first = s.points[0]["updates_per_sec"]
        latest = s.latest()
        med = s.median(window)
        delta = (latest - med) / med if med > 0 else 0.0
        lines.append(
            f"| {label} | {s.dims['engine']} | {s.dims['k']} | {s.dims['d']} "
            f"| {s.dims['source']} | {len(s.points)} | {_fmt_rate(first)} "
            f"| {_fmt_rate(latest)} | {delta:+.1%} |"
        )
    if not all_series:
        lines.append("| (no rate measurements in history) | | | | | | | | |")
    lines.append("")
    return "\n".join(lines)


def write_report(
    runs: List[RunRecord], out_dir: str, window: int = 5
) -> Tuple[str, str]:
    os.makedirs(out_dir, exist_ok=True)
    json_path = os.path.join(out_dir, "BENCH_report.json")
    md_path = os.path.join(out_dir, "BENCH_report.md")
    with open(json_path, "w") as f:
        json.dump(report_payload(runs, window), f, indent=2)
        f.write("\n")
    with open(md_path, "w") as f:
        f.write(report_markdown(runs, window))
    return json_path, md_path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench.report",
        description="cross-run perf report over the committed history",
    )
    ap.add_argument("--history", default=None,
                    help="perf-history JSONL (default: the committed one)")
    ap.add_argument("--fresh", default=None,
                    help="optional artifact tree appended as the newest run")
    ap.add_argument("--out", default=".", help="output directory")
    ap.add_argument("--window", type=int, default=5)
    args = ap.parse_args(argv)

    history_path = args.history or default_history_path()
    runs, problems = load_history(history_path)
    for p in problems:
        print(f"report,unreadable,{p}")
    if args.fresh is not None:
        try:
            fresh, fresh_problems = normalize_dir(args.fresh, strict=False)
            for p in fresh_problems:
                print(f"report,unreadable,{p}")
            if not any(r.run_id == fresh.run_id for r in runs):
                runs.append(fresh)
        except Exception as e:
            print(f"report,warning,no fresh artifacts folded in ({e})")
    if not runs:
        print(f"report,error,no runs in {history_path} and no --fresh artifacts")
        return 1
    json_path, md_path = write_report(runs, args.out, window=args.window)
    n_series = len(build_series(runs))
    print(
        f"report,written,runs={len(runs)},series={n_series},"
        f"json={json_path},md={md_path}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
