"""``repro.bench`` — the fleet-grade perf-observability plane.

The paper's claim is a *measured rate trajectory* (40k updates/s per
instance composed into 1.9B/s across a fleet); its follow-ups show those
numbers are only trustworthy when rates are tracked per-configuration
across versions and scales.  This subsystem is that measurement plane:

* :mod:`~repro.bench.models` — dataclass-validated, schema-versioned
  measurement models (``Measurement`` / ``SectionRun`` / ``RunRecord``);
* :mod:`~repro.bench.reporting` — the ``BENCH_<section>.json`` artifact
  writer every ``benchmarks/bench_*`` uses;
* :mod:`~repro.bench.parsers` — sweep multi-leg CI artifact trees into one
  normalized :class:`RunRecord`;
* :mod:`~repro.bench.history` — the committed perf-history file
  (``benchmarks/history/perf_history.jsonl``), one record per CI run;
* :mod:`~repro.bench.gate` — the trend-based regression gate: every fresh
  measurement vs the rolling-window median of its own history
  (warn >10% / fail >30% below trend; verdict true→false fails; empty
  history = clean baseline-established pass);
* :mod:`~repro.bench.experiments` — config-driven multi-leg experiment
  orchestration (``ExperimentSpec``: sections × engine × K × D × source
  from one JSON/TOML config; ``benchmarks/run.py --experiment`` drives it);
* :mod:`~repro.bench.report` — ``BENCH_report.{json,md}``: updates/s per
  engine × K × D × source across the repo's life.
"""
from .models import (  # noqa: F401
    HISTORY_SCHEMA_VERSION,
    SECTION_SCHEMA_VERSION,
    Measurement,
    ModelError,
    NormalizedMeasurement,
    RunRecord,
    SectionRun,
    params_key,
)
from .reporting import BenchmarkReport, git_branch, git_commit_hash  # noqa: F401
from .parsers import (  # noqa: F401
    find_bench_files,
    leg_label,
    normalize_dir,
    normalize_run,
    parse_section_file,
    sweep_section_runs,
)
from .history import (  # noqa: F401
    DEFAULT_HISTORY_RELPATH,
    append_fresh_artifacts,
    append_run,
    default_history_path,
    load_history,
)
from .gate import GateFinding, GateResult, gate_run, load_measurements  # noqa: F401
from .experiments import (  # noqa: F401
    SECTIONS,
    ExperimentError,
    ExperimentLeg,
    ExperimentSpec,
    run_spec,
    validate_leg_params,
)
from .report import (  # noqa: F401
    RateSeries,
    build_series,
    measurement_dims,
    report_markdown,
    report_payload,
    write_report,
)

__all__ = [
    "BenchmarkReport",
    "DEFAULT_HISTORY_RELPATH",
    "ExperimentError",
    "ExperimentLeg",
    "ExperimentSpec",
    "GateFinding",
    "GateResult",
    "HISTORY_SCHEMA_VERSION",
    "Measurement",
    "ModelError",
    "NormalizedMeasurement",
    "RateSeries",
    "RunRecord",
    "SECTIONS",
    "SECTION_SCHEMA_VERSION",
    "SectionRun",
    "append_fresh_artifacts",
    "append_run",
    "build_series",
    "default_history_path",
    "find_bench_files",
    "gate_run",
    "git_branch",
    "git_commit_hash",
    "leg_label",
    "load_history",
    "load_measurements",
    "measurement_dims",
    "normalize_dir",
    "normalize_run",
    "params_key",
    "parse_section_file",
    "report_markdown",
    "report_payload",
    "run_spec",
    "sweep_section_runs",
    "validate_leg_params",
    "write_report",
]
