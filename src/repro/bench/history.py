"""The committed perf-history file: ``benchmarks/history/perf_history.jsonl``.

One line per CI run (a :class:`~repro.bench.models.RunRecord`), appended by
the ``perf-history`` CI job and committed on ``main`` — the repo carries its
own rate trajectory, so the regression gate tests fresh numbers against a
rolling-window *trend* instead of one possibly-noisy previous sample, and
the report generator can plot updates/s per engine × K × D × source across
the repo's life.

CLI::

    python -m repro.bench.history append --fresh <artifact-tree> \
        [--history benchmarks/history/perf_history.jsonl] [--run-id ID]
    python -m repro.bench.history show [--history ...] [--last N]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional, Tuple

from .models import ModelError, RunRecord
from .parsers import normalize_dir

#: repo-relative location of the committed history file
DEFAULT_HISTORY_RELPATH = os.path.join("benchmarks", "history", "perf_history.jsonl")


def default_history_path() -> str:
    """The committed history file, resolved relative to this checkout."""
    repo_root = os.path.dirname(  # src/repro/bench -> src/repro -> src -> repo
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    )
    return os.path.join(repo_root, DEFAULT_HISTORY_RELPATH)


def load_history(path: str, strict: bool = False) -> Tuple[List[RunRecord], List[str]]:
    """Read the history file oldest-first.

    Returns ``(records, problems)``.  A missing file is an empty history
    (the baseline-established case), never an error.  Corrupt lines raise
    under ``strict`` and are skipped-with-note otherwise — the gate must
    keep working even if one bad line ever lands.
    """
    records: List[RunRecord] = []
    problems: List[str] = []
    if not os.path.exists(path):
        return records, problems
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                records.append(RunRecord.from_json(json.loads(line)))
            except (json.JSONDecodeError, ModelError) as e:
                msg = f"{path}:{lineno}: unreadable history line ({e})"
                if strict:
                    raise ModelError(msg) from None
                problems.append(msg)
    return records, problems


def append_run(record: RunRecord, path: str) -> str:
    """Append one validated record as a JSONL line; returns ``path``."""
    record.validate()
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "a") as f:
        f.write(record.to_jsonl() + "\n")
    return path


def append_fresh_artifacts(
    fresh_dir: str,
    history_path: str,
    run_id: Optional[str] = None,
    dedupe_run_id: bool = True,
) -> RunRecord:
    """Normalize an artifact tree and append it to the history.

    ``dedupe_run_id=True`` makes the append idempotent per CI run: a
    re-triggered workflow with the same ``run_id`` replaces nothing and
    appends nothing the second time (the first record stands).
    """
    record, _ = normalize_dir(fresh_dir, run_id=run_id, strict=True)
    if dedupe_run_id:
        existing, _ = load_history(history_path)
        if any(r.run_id == record.run_id for r in existing):
            return record
    append_run(record, history_path)
    return record


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.bench.history",
        description=__doc__.splitlines()[0],
    )
    sub = ap.add_subparsers(dest="cmd", required=True)

    ap_append = sub.add_parser(
        "append", help="normalize an artifact tree and append it"
    )
    ap_append.add_argument("--fresh", required=True,
                           help="directory tree holding BENCH_*.json artifacts")
    ap_append.add_argument("--history", default=None,
                           help=f"history file (default: {DEFAULT_HISTORY_RELPATH})")
    ap_append.add_argument("--run-id", default=None,
                           help="override the run id (default: artifacts' "
                                "ci_run_id, else local-<commit>)")
    ap_append.add_argument("--allow-duplicate-run-id", action="store_true",
                           help="append even when the run id is already in "
                                "the history (default: idempotent skip)")

    ap_show = sub.add_parser("show", help="print the history summary")
    ap_show.add_argument("--history", default=None)
    ap_show.add_argument("--last", type=int, default=10)

    args = ap.parse_args(argv)
    history_path = args.history or default_history_path()

    if args.cmd == "append":
        try:
            record = append_fresh_artifacts(
                args.fresh,
                history_path,
                run_id=args.run_id,
                dedupe_run_id=not args.allow_duplicate_run_id,
            )
        except ModelError as e:
            print(f"history,error,{e}")
            return 1
        print(
            f"history,appended,run_id={record.run_id},"
            f"commit={record.git_commit_hash[:12]},"
            f"sections={'+'.join(record.sections())},"
            f"legs={'+'.join(l or '-' for l in record.legs())},"
            f"measurements={len(record.measurements)},path={history_path}"
        )
        return 0

    records, problems = load_history(history_path)
    for p in problems:
        print(f"history,unreadable,{p}")
    print(f"history,{len(records)} run(s),path={history_path}")
    for r in records[-args.last:]:
        rates = sum(1 for m in r.measurements if m.updates_per_sec is not None)
        print(
            f"history,run,run_id={r.run_id},commit={r.git_commit_hash[:12]},"
            f"branch={r.git_branch},end={r.run_end_ts},"
            f"jax={r.jax_version or '?'},measurements={len(r.measurements)},"
            f"rates={rates}"
        )
    return 0


if __name__ == "__main__":
    sys.exit(main())
