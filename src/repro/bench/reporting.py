"""Structured benchmark output: ``BENCH_<section>.json`` alongside the CSV.

Canonical home of the artifact writer (``benchmarks/reporting.py`` is a
thin shim).  Schema loosely follows tt-github-actions'
``CompleteBenchmarkRun``: one run record with git/host provenance plus a
flat ``measurements`` list, so CI can upload the files as artifacts, the
perf-history appender (:mod:`repro.bench.history`) can normalize them, and
the trend gate can diff runs by key:

    {
      "schema_version": 1,
      "section": "scaling",
      "git_commit_hash": "<sha or 'unknown'>",
      "git_branch": "<branch or 'unknown'>",
      "run_start_ts": "2026-07-30T12:00:00+00:00",
      "run_end_ts": "...",
      "host": {"hostname": ..., "backend": "cpu", "device_count": 8,
               "jax_version": "0.4.37"},
      "ci_run_id": "1234567890",        # GITHUB_RUN_ID; absent locally
      "measurements": [
        {"name": "packed_rate", "params": {"k_per_device": 8, ...},
         "updates_per_sec": 1.2e7, "wall_s": 0.41, ...extras}
      ]
    }

Every ``bench_*.main`` builds a :class:`BenchmarkReport`, ``add()``s one
measurement per CSV line it prints, and ``write()``s on exit.  The output
directory is ``--json-dir`` via ``benchmarks.run`` (environment variable
``BENCH_JSON_DIR``; default: current directory).
"""
from __future__ import annotations

import dataclasses
import json
import os
import platform
import subprocess
from datetime import datetime, timezone
from typing import Any, Dict, List

from .models import SECTION_SCHEMA_VERSION as SCHEMA_VERSION  # noqa: F401


def _git(*args: str) -> str:
    try:
        out = subprocess.run(
            ["git", *args],
            cwd=os.path.dirname(os.path.abspath(__file__)),
            capture_output=True,
            text=True,
            timeout=10,
        )
        if out.returncode == 0:
            return out.stdout.strip()
    except (OSError, subprocess.SubprocessError):
        pass
    return ""


def git_commit_hash() -> str:
    return os.environ.get("GITHUB_SHA") or _git("rev-parse", "HEAD") or "unknown"


def git_branch() -> str:
    """The branch the run measures, robust to detached/CI checkouts.

    ``GITHUB_REF_NAME`` wins (actions check out a detached SHA, where git
    itself can only say ``HEAD``); a local detached checkout likewise
    reports the literal ``HEAD``, which is not a branch — fall through to
    ``unknown`` rather than let history entries fork under a fake branch
    name.
    """
    env = os.environ.get("GITHUB_REF_NAME")
    if env:
        return env
    branch = _git("rev-parse", "--abbrev-ref", "HEAD")
    if branch and branch != "HEAD":
        return branch
    return "unknown"


def _now() -> str:
    return datetime.now(timezone.utc).isoformat(timespec="seconds")


def _host_info() -> Dict[str, Any]:
    info: Dict[str, Any] = {
        "hostname": platform.node(),
        "platform": platform.platform(),
        "python": platform.python_version(),
    }
    try:
        import jax

        info["jax_version"] = jax.__version__
        info["backend"] = jax.default_backend()
        info["device_count"] = jax.device_count()
    except Exception:  # pragma: no cover - jax import should never fail here
        pass
    return info


@dataclasses.dataclass
class BenchmarkReport:
    """Collects one section's measurements and serializes them to JSON."""

    section: str
    measurements: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    run_start_ts: str = dataclasses.field(default_factory=_now)

    def add(
        self,
        name: str,
        params: Dict[str, Any] | None = None,
        updates_per_sec: float | None = None,
        wall_s: float | None = None,
        **extra: Any,
    ) -> None:
        m: Dict[str, Any] = {"name": name, "params": dict(params or {})}
        if updates_per_sec is not None:
            m["updates_per_sec"] = float(updates_per_sec)
        if wall_s is not None:
            m["wall_s"] = float(wall_s)
        m.update(extra)
        self.measurements.append(m)

    def payload(self) -> Dict[str, Any]:
        payload = {
            "schema_version": SCHEMA_VERSION,
            "section": self.section,
            "git_commit_hash": git_commit_hash(),
            "git_branch": git_branch(),
            "run_start_ts": self.run_start_ts,
            "run_end_ts": _now(),
            "host": _host_info(),
            "measurements": self.measurements,
        }
        # tie the artifact back to the CI run that produced it (absent in
        # local runs; measurement identity keys on section+leg+name+params)
        ci_run_id = os.environ.get("GITHUB_RUN_ID")
        if ci_run_id:
            payload["ci_run_id"] = ci_run_id
        return payload

    def write(self, out_dir: str | None = None) -> str:
        """Write ``BENCH_<section>.json``; returns the path written."""
        out_dir = out_dir or os.environ.get("BENCH_JSON_DIR") or "."
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"BENCH_{self.section}.json")
        with open(path, "w") as f:
            json.dump(self.payload(), f, indent=2)
            f.write("\n")
        print(f"json,section={self.section},path={path}", flush=True)
        return path
