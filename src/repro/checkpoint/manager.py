"""Checkpoint/restart for fault tolerance at cluster scale.

Design (works the same on 1 CPU and 1,100 nodes):

* **Content**: the full train state (params, optimizer, data-stream cursor,
  hierarchical-array layers, RNG) as a flat ``{path: ndarray}`` dict saved
  with numpy's npz container + a json manifest (step, cursor, config hash,
  pytree structure).  No pickle — restart works across process versions.
* **Atomicity**: write to ``<dir>/tmp-<step>`` then ``os.replace`` into
  ``ckpt-<step>`` — a crash mid-write can never corrupt the latest ckpt.
* **Async**: ``save_async`` snapshots device arrays to host (blocking only
  on device->host copy) and hands the serialization to a daemon thread, so
  the train loop overlaps checkpoint IO with compute — at multi-GB state
  this is the difference between a stalled and a busy TPU.
* **Sharded state**: each host saves only the shards it owns
  (``addressable_shards``); ``restore`` reassembles per-host and
  ``jax.device_put`` applies the target sharding.  On this single-host
  container that degenerates to a full save, exercising the same code path.
* **Retention**: keep the newest ``keep`` checkpoints, best-effort cleanup.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state, extra: Optional[Dict[str, Any]] = None):
        """Synchronous atomic save."""
        host_state = jax.tree.map(np.asarray, state)  # device -> host
        self._write(step, host_state, extra or {})

    def save_async(self, step: int, state, extra: Optional[Dict[str, Any]] = None):
        """Device->host copy now; serialization on a background thread.

        The snapshot must be an owned copy, not ``np.asarray``: on the CPU
        backend that can be a zero-copy *view* of the device buffer, and a
        donating update step dispatched after this call mutates the buffer
        in place — the background serializer would then write torn state
        (caught by the serve-loop checkpoint/replay parity test).
        """
        self.wait()  # one outstanding save at a time
        host_state = jax.tree.map(lambda x: np.array(x, copy=True), state)

        def work():
            try:
                self._write(step, host_state, extra or {})
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_state, extra: Dict[str, Any]):
        tmp = os.path.join(self.dir, f"tmp-{step}-{os.getpid()}")
        final = os.path.join(self.dir, f"ckpt-{step:09d}")
        os.makedirs(tmp, exist_ok=True)
        flat = _flatten(host_state)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "extra": extra,
            "keys": sorted(flat.keys()),
            "time": time.time(),
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"ckpt-{s:09d}"), ignore_errors=True)

    # ------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"ckpt-(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self, state_like, step: Optional[int] = None, shardings=None
    ) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the structure of ``state_like``; optionally apply a
        sharding pytree (elastic restart onto a different mesh re-shards
        here)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        path = os.path.join(self.dir, f"ckpt-{step:09d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        arrays = np.load(os.path.join(path, "arrays.npz"))
        leaves_like, treedef = jax.tree_util.tree_flatten_with_path(state_like)
        leaves = []
        for kp, like in leaves_like:
            key = jax.tree_util.keystr(kp)
            arr = arrays[key]
            leaves.append(arr.astype(like.dtype) if hasattr(like, "dtype") else arr)
        state = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(state_like), leaves
        )
        if shardings is not None:
            state = jax.tree.map(jax.device_put, state, shardings)
        return state, manifest["extra"] | {"step": manifest["step"]}
