"""Checkpoint/restart for fault tolerance at cluster scale.

Design (works the same on 1 CPU and 1,100 nodes):

* **Content**: the full train state (params, optimizer, data-stream cursor,
  hierarchical-array layers, RNG) as a flat ``{path: ndarray}`` dict saved
  with numpy's npz container + a json manifest (step, cursor, config hash,
  pytree structure).  No pickle — restart works across process versions.
* **Atomicity**: write to ``<dir>/tmp-<step>`` then ``os.replace`` into
  ``ckpt-<step>`` — a crash mid-write can never corrupt the latest ckpt.
* **Async**: ``save_async`` snapshots device arrays to host (blocking only
  on device->host copy) and hands the serialization to a daemon thread, so
  the train loop overlaps checkpoint IO with compute — at multi-GB state
  this is the difference between a stalled and a busy TPU.
* **Sharded state**: each host saves only the shards it owns
  (``addressable_shards``); ``restore`` reassembles per-host and
  ``jax.device_put`` applies the target sharding.  On this single-host
  container that degenerates to a full save, exercising the same code path.
* **Retention**: keep the newest ``keep`` checkpoints, best-effort cleanup.
* **Integrity**: the manifest records the CRC32 and byte length of
  ``arrays.npz``; ``restore`` verifies them and, with ``fallback=True``
  (the default when no step is pinned), walks back generation-by-
  generation past torn/corrupt/unreadable checkpoints to the newest one
  that verifies — a lying disk costs one checkpoint interval, not the run.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
import zlib
from typing import TYPE_CHECKING, Any, Dict, Optional, Tuple

import jax
import numpy as np

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.faults import FaultPlan


class CheckpointDamaged(RuntimeError):
    """One specific checkpoint generation failed to verify or load."""


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = jax.tree_util.keystr(path)
        flat[key] = np.asarray(leaf)
    return flat


def _treedef_of(tree):
    return jax.tree_util.tree_structure(tree)


class CheckpointManager:
    def __init__(
        self,
        directory: str,
        keep: int = 3,
        faults: "Optional[FaultPlan]" = None,
    ):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        if faults is None:
            from repro.faults import FaultPlan as _FP

            faults = _FP.from_env()
        self._faults = faults

    # ------------------------------------------------------------- save
    def save(self, step: int, state, extra: Optional[Dict[str, Any]] = None):
        """Synchronous atomic save."""
        host_state = jax.tree.map(np.asarray, state)  # device -> host
        self._write(step, host_state, extra or {})

    def save_async(self, step: int, state, extra: Optional[Dict[str, Any]] = None):
        """Device->host copy now; serialization on a background thread.

        The snapshot must be an owned copy, not ``np.asarray``: on the CPU
        backend that can be a zero-copy *view* of the device buffer, and a
        donating update step dispatched after this call mutates the buffer
        in place — the background serializer would then write torn state
        (caught by the serve-loop checkpoint/replay parity test).
        """
        self.wait()  # one outstanding save at a time
        host_state = jax.tree.map(lambda x: np.array(x, copy=True), state)

        def work():
            try:
                self._write(step, host_state, extra or {})
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def set_faults(self, faults: "Optional[FaultPlan]") -> None:
        """Attach (or clear) a fault plan after construction — lets a serve
        loop share one plan instance with the session's lazily-created
        manager instead of each building its own from the environment."""
        self._faults = faults

    def _write(self, step: int, host_state, extra: Dict[str, Any]):
        tmp = os.path.join(self.dir, f"tmp-{step}-{os.getpid()}")
        final = os.path.join(self.dir, f"ckpt-{step:09d}")
        os.makedirs(tmp, exist_ok=True)
        flat = _flatten(host_state)
        npz_path = os.path.join(tmp, "arrays.npz")
        np.savez(npz_path, **flat)

        with open(npz_path, "rb") as f:
            payload = f.read()
        manifest = {
            "step": step,
            "extra": extra,
            "keys": sorted(flat.keys()),
            "time": time.time(),
            "arrays_bytes": len(payload),
            "arrays_crc32": zlib.crc32(payload) & 0xFFFFFFFF,
        }

        # Fault sites damage the payload *after* the manifest integrity
        # fields were computed over the good bytes — exactly the shape of a
        # disk that lies between write and publish.  The publish below still
        # happens, so the damage lands in a *visible* generation.
        if self._faults is not None:
            spec = self._faults.fire("checkpoint.torn_write", cursor=step)
            if spec is not None:
                keep = int(spec.args.get("keep_bytes", len(payload) // 2))
                with open(npz_path, "r+b") as f:
                    f.truncate(max(0, keep))
            spec = self._faults.fire("checkpoint.corrupt_payload", cursor=step)
            if spec is not None:
                off = min(
                    int(spec.args.get("offset", len(payload) // 2)),
                    max(0, len(payload) - 1),
                )
                with open(npz_path, "r+b") as f:
                    f.seek(off)
                    b = f.read(1)
                    f.seek(off)
                    f.write(bytes([(b[0] ^ 0xFF) if b else 0xFF]))
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)  # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"ckpt-{s:09d}"), ignore_errors=True)

    # ------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"ckpt-(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_verified(self, step: int, state_like):
        """Load + integrity-check one generation; raises
        :class:`CheckpointDamaged` on any failure mode a bad disk can
        produce (torn payload, flipped bytes, unreadable zip, missing
        keys, garbled manifest)."""
        path = os.path.join(self.dir, f"ckpt-{step:09d}")
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            npz_path = os.path.join(path, "arrays.npz")
            with open(npz_path, "rb") as f:
                payload = f.read()
            # pre-CRC manifests (older generations) skip the byte checks
            want_bytes = manifest.get("arrays_bytes")
            if want_bytes is not None and len(payload) != want_bytes:
                raise CheckpointDamaged(
                    f"ckpt-{step:09d}: arrays.npz is {len(payload)} bytes, "
                    f"manifest says {want_bytes} (torn write)"
                )
            want_crc = manifest.get("arrays_crc32")
            if want_crc is not None:
                got = zlib.crc32(payload) & 0xFFFFFFFF
                if got != want_crc:
                    raise CheckpointDamaged(
                        f"ckpt-{step:09d}: arrays.npz crc32 {got:#010x} != "
                        f"manifest {want_crc:#010x} (corrupt payload)"
                    )
            arrays = np.load(npz_path)
            leaves_like, _ = jax.tree_util.tree_flatten_with_path(state_like)
            leaves = []
            for kp, like in leaves_like:
                key = jax.tree_util.keystr(kp)
                arr = arrays[key]
                leaves.append(
                    arr.astype(like.dtype) if hasattr(like, "dtype") else arr
                )
        except CheckpointDamaged:
            raise
        except Exception as err:
            # np.load raises zipfile.BadZipFile / OSError / KeyError /
            # EOFError depending on where the damage lands — any load
            # failure of one generation is damage, not a caller bug
            raise CheckpointDamaged(f"ckpt-{step:09d}: {err!r}") from err
        state = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(state_like), leaves
        )
        return state, manifest

    def restore(
        self,
        state_like,
        step: Optional[int] = None,
        shardings=None,
        fallback: Optional[bool] = None,
    ) -> Tuple[Any, Dict[str, Any]]:
        """Restore into the structure of ``state_like``; optionally apply a
        sharding pytree (elastic restart onto a different mesh re-shards
        here).

        ``fallback`` controls damage handling: ``True`` walks back past
        torn/corrupt generations to the newest one that verifies (raising
        only when *no* generation loads); ``False`` raises
        :class:`CheckpointDamaged` on the requested generation.  Default:
        fall back exactly when no ``step`` was pinned.
        """
        if fallback is None:
            fallback = step is None
        steps = self.all_steps()
        if step is not None:
            candidates = [s for s in steps if s <= step]
            if step not in steps:
                raise FileNotFoundError(
                    f"no checkpoint for step {step} in {self.dir}"
                )
        else:
            candidates = steps
        if not candidates:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")

        last_err: Optional[CheckpointDamaged] = None
        for s in reversed(candidates):
            try:
                state, manifest = self._load_verified(s, state_like)
            except CheckpointDamaged as err:
                last_err = err
                if not fallback:
                    raise
                continue
            if shardings is not None:
                state = jax.tree.map(jax.device_put, state, shardings)
            return state, manifest["extra"] | {"step": manifest["step"]}
        raise CheckpointDamaged(
            f"all {len(candidates)} checkpoint generation(s) in {self.dir} "
            f"are damaged; last error: {last_err}"
        )
