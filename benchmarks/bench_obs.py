"""Observability-plane benchmark: what does instrumentation cost?

The obs plane's contract is the repro.faults one: **off means absent**
(every site compiles down to one ``is not None`` check) and **on means
cheap** (per-thread shard histograms, no locks on the hot path).  This
bench puts numbers on both:

* **rate_metrics_off** — baseline: a pre-generated R-MAT stream through
  the full serve loop (publishing views, so every instrumented stage
  executes) with ``ServeConfig(metrics=False)``, best of ``repeats``;
* **rate_metrics_on** — the identical stream and session shape with
  ``metrics=True``: every dispatch, publish, flush and view build timed
  into live histograms;
* the CI-gated verdict ``obs_overhead``: the enabled run must sustain at
  least ``1 - OVERHEAD_CEILING`` of the disabled rate, the two drained
  snapshots must be **bit-identical** (instrumentation may not perturb
  results), and a METRICS scrape over a live D4MF socket must return
  summaries bit-equal to the in-process registry (the exactness
  contract, exercised end to end).

Emits ``BENCH_obs.json`` on the ``benchmarks/reporting.py`` schema, so
the trend gate and perf history track both rates and the verdict.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.reporting import BenchmarkReport
from repro import d4m, serve
from repro.obs import hist as obs_hist

OVERHEAD_CEILING = 0.02  # enabled may cost at most 2% of the disabled rate

#: ingest-side histograms a scrape cannot perturb (quiescent after feed)
_QUIET_HISTS = ("serve.update_dispatch_ns", "serve.publish_ns",
                "router.flush_ns", "session.view_build_ns")


def _config(k: int, batch: int, top: int) -> d4m.StreamConfig:
    return d4m.StreamConfig(
        cuts=(2 * batch, 16 * batch),
        top_capacity=top,
        batch_size=batch,
        instances_per_device=k,
        snapshot_cap=4 * top,
    )


def _workload(batches: int, batch: int, scale: int, seed: int = 0):
    src = serve.RMATSource(
        batches * batch, chunk_records=batch, scale=scale, seed=seed,
        pregenerate=True,
    )
    rows, cols, vals = zip(*src.chunks())
    return np.concatenate(rows), np.concatenate(cols), np.concatenate(vals)


def _warmup(sess: d4m.D4MStream, r, c, v, batch: int) -> None:
    warm = sess.serve(
        serve.ArraySource(r[: 2 * batch], c[: 2 * batch], v[: 2 * batch],
                          chunk_records=batch),
        max_latency_ms=1e9, publish_every=1,
    )
    assert warm.drained
    sess.reset()


def _timed_leg(k: int, batch: int, top: int, r, c, v, publish_every: int,
               metrics: bool, repeats: int):
    """Best-of-``repeats`` served ingest rate; returns (rate, wall, snap)
    where snap is the last repeat's drained snapshot triples."""
    best_rate, best_wall, snap = 0.0, 0.0, None
    for _ in range(repeats):
        sess = d4m.D4MStream(_config(k, batch, top))
        _warmup(sess, r, c, v, batch)
        src = serve.ArraySource(r, c, v, chunk_records=batch)
        server = serve.D4MServer(
            sess, src,
            d4m.ServeConfig(max_latency_ms=1e9, publish_every=publish_every,
                            drain_timeout_s=600.0, metrics=metrics),
        ).start()
        assert server.join(timeout=600)
        report = server.report()
        assert report.drained and report.records_fed == r.shape[0]
        assert report.records_dropped == 0
        if report.ingest_rate > best_rate:
            best_rate, best_wall = report.ingest_rate, report.wall_s
        s = sess.snapshot()
        nnz = int(s.nnz)
        snap = (np.asarray(s.rows)[:nnz].copy(),
                np.asarray(s.cols)[:nnz].copy(),
                np.asarray(s.vals)[:nnz].copy())
    return best_rate, best_wall, snap


def _quiesce_hists(server, names, timeout_s: float = 30.0) -> None:
    """Wait until the named histograms stop changing: the feed thread
    publishes the view *before* recording its publish/view-build spans, so
    a scrape issued the instant a covering view appears can race the last
    ``record()`` calls."""
    prev = None
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        cur = {n: server.metrics.dump()["histograms"][n] for n in names}
        if cur == prev:
            return
        prev = cur
        time.sleep(0.05)
    raise AssertionError("ingest-side histograms never went quiescent")


def _scrape_exact(k: int, batch: int, top: int, r, c, v,
                  publish_every: int) -> bool:
    """Serve over a real loopback socket with metrics on, scrape via the
    METRICS op, and compare the wire summaries to the in-process registry
    for every quiescent histogram — must be equal integers, bit for bit."""
    n = r.shape[0]
    sess = d4m.D4MStream(_config(k, batch, top))
    _warmup(sess, r, c, v, batch)
    src = serve.TCPSource(port=0, encoding="binary", linger=False)
    server = serve.D4MServer(
        sess, src,
        d4m.ServeConfig(max_latency_ms=1e9, publish_every=publish_every,
                        drain_timeout_s=600.0, metrics=True),
    ).start()
    exact = True
    with serve.QueryClient("127.0.0.1", src.port, timeout_s=120.0) as qc:
        for lo in range(0, n, 4 * batch):
            qc.insert(r[lo:lo + 4 * batch], c[lo:lo + 4 * batch],
                      v[lo:lo + 4 * batch])
        deadline = time.monotonic() + 120
        while True:
            rep = qc.request("stats")
            assert rep.ok
            if rep.scalars["records"] == n:
                break
            assert time.monotonic() < deadline, "stream never fully published"
            time.sleep(0.01)
        _quiesce_hists(server, _QUIET_HISTS)
        rep = qc.metrics()
        assert rep.ok
        local = server.metrics.dump()["histograms"]
        for name in _QUIET_HISTS:
            st = local[name]
            if obs_hist.state_count(st) == 0:
                exact = False
            if not np.array_equal(rep.arrays[f"hist.{name}.counts"],
                                  np.asarray(st["counts"], np.int64)):
                exact = False
            if rep.scalars["summaries"].get(name) \
                    != obs_hist.summarize_state(st):
                exact = False
    assert server.join(timeout=600)
    return exact


def _bit_identical(a, b) -> bool:
    return all(np.array_equal(x, y) for x, y in zip(a, b))


def main(
    smoke: bool = False,
    k: int = 8,
    batches: int | None = None,
    batch: int | None = None,
    scale: int | None = None,
    publish_every: int | None = None,
    repeats: int = 3,
):
    batches = batches if batches is not None else (60 if smoke else 400)
    batch = batch if batch is not None else (256 if smoke else 512)
    scale = scale if scale is not None else (14 if smoke else 18)
    publish_every = publish_every if publish_every is not None else (
        6 if smoke else 10
    )
    assert batches % publish_every == 0
    top = int(batches * batch * 1.25)
    r, c, v = _workload(batches, batch, scale)
    params = {
        "k_per_device": k, "batches": batches, "batch": batch,
        "rmat_scale": scale, "publish_every": publish_every,
        "repeats": repeats,
    }
    report = BenchmarkReport("obs")

    off_rate, off_wall, off_snap = _timed_leg(
        k, batch, top, r, c, v, publish_every, metrics=False, repeats=repeats
    )
    print(f"obs,metrics_off,k={k},rate={off_rate:,.0f}/s,"
          f"wall_s={off_wall:.3f}", flush=True)
    report.add("rate_metrics_off", params=params,
               updates_per_sec=off_rate, wall_s=off_wall)

    on_rate, on_wall, on_snap = _timed_leg(
        k, batch, top, r, c, v, publish_every, metrics=True, repeats=repeats
    )
    overhead = 1.0 - on_rate / off_rate
    print(f"obs,metrics_on,k={k},rate={on_rate:,.0f}/s,"
          f"wall_s={on_wall:.3f},overhead={overhead:.4f}", flush=True)
    report.add("rate_metrics_on", params=params,
               updates_per_sec=on_rate, wall_s=on_wall,
               overhead=float(overhead))

    bit = _bit_identical(off_snap, on_snap)
    exact = _scrape_exact(k, batch, top, r, c, v, publish_every)
    passed = bool(overhead <= OVERHEAD_CEILING and bit and exact)
    print(f"verdict,obs_overhead,{passed},k={k},overhead={overhead:.4f},"
          f"ceiling={OVERHEAD_CEILING},bit_identical={bit},"
          f"scrape_exact={exact}")
    report.add(
        "obs_overhead",
        params={**params, "ceiling": OVERHEAD_CEILING},
        passed=passed,
        overhead=float(overhead),
        bit_identical=bool(bit),
        scrape_exact=bool(exact),
    )
    report.write()
    return {"overhead": overhead, "bit_identical": bit, "scrape_exact": exact}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--batches", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--scale", type=int, default=None)
    ap.add_argument("--publish-every", type=int, default=None)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()
    main(
        smoke=args.smoke,
        k=args.k,
        batches=args.batches,
        batch=args.batch,
        scale=args.scale,
        publish_every=args.publish_every,
        repeats=args.repeats,
    )
