"""LM integration benchmark: hierarchical sparse embedding-gradient
accumulation vs. dense accumulation (DESIGN.md section 3.4).

A gradient-accumulation window of M microbatches touches <= M*T distinct
vocab rows out of V (hypersparse for V in the 32 K-262 K range).  The dense
baseline materializes + adds a [V, d] f32 gradient every microbatch
(bytes ~ M * V * d * 4 * 2); the hierarchical accumulator ingests (id, row)
pairs (bytes ~ M * T * d * 4 * few) and scatters once per optimizer step.

Reported: wall time per microbatch on CPU, the modeled HBM bytes each path
moves on the TPU target, and numerical equivalence of the flushed gradient.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.reporting import BenchmarkReport
from repro.sparse import row_accum as RA


def run(v: int, d: int, t_tokens: int, micro: int, zipf: float = 1.2,
        report: BenchmarkReport | None = None):
    rng = np.random.default_rng(0)
    # zipf-ish token draw — the same power-law structure as R-MAT streams
    ranks = np.arange(1, v + 1, dtype=np.float64)
    probs = ranks**-zipf
    probs /= probs.sum()
    ids_all = rng.choice(v, size=(micro, t_tokens), p=probs).astype(np.int32)
    rows_all = rng.normal(size=(micro, t_tokens, d)).astype(np.float32) * 0.01

    # ---- dense baseline --------------------------------------------------
    @jax.jit
    def dense_step(acc, ids, rows):
        return acc.at[ids].add(rows)

    acc = jnp.zeros((v, d), jnp.float32)
    acc = dense_step(acc, jnp.asarray(ids_all[0]), jnp.asarray(rows_all[0]))
    jax.block_until_ready(acc)
    acc = jnp.zeros((v, d), jnp.float32)
    t0 = time.perf_counter()
    for m in range(micro):
        acc = dense_step(acc, jnp.asarray(ids_all[m]), jnp.asarray(rows_all[m]))
    jax.block_until_ready(acc)
    dense_us = (time.perf_counter() - t0) / micro * 1e6

    # ---- hierarchical sparse accumulator ----------------------------------
    cuts = (2 * t_tokens, 8 * t_tokens)
    h = RA.hier_init(cuts, top_capacity=micro * t_tokens, batch=t_tokens, d=d)
    upd = jax.jit(lambda hh, i, r: RA.hier_update(hh, i, r, cuts), donate_argnums=(0,))
    h = upd(h, jnp.asarray(ids_all[0]), jnp.asarray(rows_all[0]))
    jax.block_until_ready(h)
    h = RA.hier_init(cuts, top_capacity=micro * t_tokens, batch=t_tokens, d=d)
    t0 = time.perf_counter()
    for m in range(micro):
        h = upd(h, jnp.asarray(ids_all[m]), jnp.asarray(rows_all[m]))
    jax.block_until_ready(h)
    hier_us = (time.perf_counter() - t0) / micro * 1e6
    flushed = RA.hier_flush(h)
    assert not bool(RA.hier_overflowed(h))

    # numerical equivalence
    got = RA.to_dense(flushed, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(acc), rtol=1e-4, atol=1e-5)

    # modeled TPU HBM traffic per accumulation window
    dense_bytes = micro * v * d * 4 * 2  # read+write full table each microbatch
    distinct = len(np.unique(ids_all))
    hier_bytes = micro * t_tokens * d * 4 * 3 + distinct * d * 4 * 2
    print(
        f"embed_grad,V={v},d={d},tok/mb={t_tokens},micro={micro},"
        f"dense_us={dense_us:.0f},hier_us={hier_us:.0f},"
        f"hbm_bytes_dense={dense_bytes/1e9:.2f}GB,hbm_bytes_hier={hier_bytes/1e9:.3f}GB,"
        f"traffic_saving={dense_bytes/hier_bytes:.0f}x,distinct_ids={distinct}"
    )
    if report is not None:
        report.add(
            "embed_grad",
            params={"V": v, "d": d, "tokens_per_microbatch": t_tokens, "micro": micro},
            updates_per_sec=t_tokens / (hier_us / 1e6),
            wall_s=hier_us / 1e6 * micro,
            dense_us=dense_us,
            hier_us=hier_us,
            hbm_bytes_dense=dense_bytes,
            hbm_bytes_hier=hier_bytes,
            traffic_saving=dense_bytes / hier_bytes,
            distinct_ids=int(distinct),
        )


def main(smoke: bool = False):
    report = BenchmarkReport("embed_grad")
    if smoke:
        run(v=32_000, d=64, t_tokens=512, micro=4, report=report)
    else:
        run(v=32_000, d=256, t_tokens=2048, micro=8, report=report)
        run(v=262_144, d=256, t_tokens=2048, micro=8, report=report)
    report.write()


if __name__ == "__main__":
    main()
