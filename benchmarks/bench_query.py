"""Query-plane benchmark: what does querying a live stream cost?

The tentpole claim of the online query plane is that snapshot-isolated
queries ride along with ingest nearly for free: views are published at
microbatch boundaries off the device path, degree vectors are maintained
incrementally on the feed thread, and the executor answers on the source's
reader thread against immutable buffers.  This bench puts a number on
"nearly":

* **ingest_only_rate** — the baseline: a pre-generated R-MAT stream pushed
  through a real loopback TCP socket with the query plane armed
  (``publish_every`` set, views publishing) but no client ever asking;
* **mixed_rate** — the same stream, same socket path, while a second
  connection hammers the live views with a rotating query mix (stats /
  degrees / top_k / row / get), measuring sustained **query QPS** on the
  side;
* the CI-gated verdict ``query_cost``: the mixed run must sustain at least
  ``1 - COST_CEILING`` of the ingest-only rate AND the final live-view
  degrees answered *over the wire* must be bit-identical to the drained
  session's snapshot reduction (unit-weight R-MAT traffic, so the
  incremental fold's exactness contract applies).

Emits ``BENCH_query.json`` on the ``benchmarks/reporting.py`` schema, so
``regression_gate.py`` and the trend gate track both rates, the QPS, and
the verdict automatically.
"""
from __future__ import annotations

import argparse
import threading
import time

import numpy as np

from benchmarks.reporting import BenchmarkReport
from repro import d4m, serve
from repro.core import analytics

COST_CEILING = 0.10  # mixed ingest may cost at most this fraction of baseline


def _config(k: int, batch: int, top: int) -> d4m.StreamConfig:
    return d4m.StreamConfig(
        cuts=(2 * batch, 16 * batch),
        top_capacity=top,
        batch_size=batch,
        instances_per_device=k,
        snapshot_cap=4 * top,
    )


def _workload(batches: int, batch: int, scale: int, seed: int = 0):
    src = serve.RMATSource(
        batches * batch, chunk_records=batch, scale=scale, seed=seed,
        pregenerate=True,
    )
    rows, cols, vals = zip(*src.chunks())
    return np.concatenate(rows), np.concatenate(cols), np.concatenate(vals)


def _warmup(sess: d4m.D4MStream, r, c, v, batch: int, space: int) -> None:
    """Compile the update, publish (snapshot), and degree-lift programs
    through the same code path, then reset the state."""
    warm = sess.serve(
        serve.ArraySource(r[: 2 * batch], c[: 2 * batch], v[: 2 * batch],
                          chunk_records=batch),
        max_latency_ms=1e9, publish_every=1,
    )
    assert warm.drained
    # prime the degree lift at every power-of-two bucket the growing
    # tracker vectors can reach (vertex count <= space), so neither timed
    # leg pays a first-touch trace the other has already cached
    b = 256
    while b <= space:
        ids = np.zeros(b, np.int32)
        vals = np.zeros(b, sess.dtype)
        analytics.degrees_from_vectors(
            ids, vals, ids, vals, sess.plan.snapshot_cap, sess.sr, sess.dtype
        )
        b *= 2
    # prime the query-op device programs at the hammer's arg shapes, so the
    # mixed leg measures steady-state QPS, not first-call compiles
    view = sess.latest_view()
    view.degrees()
    view.top_k(10, "out")
    view.row(0)
    view.get(0, 0)
    view.stats()
    sess.reset()


def _hammer(port: int, n_records: int, space: int, sent_done, out) -> None:
    """Rotate the query mix against the live views until a view covering
    the whole stream has answered a degrees query, then disconnect (the
    open client counts as a producer, so leaving would stall the drain)."""
    rng = np.random.default_rng(1)
    count = 0
    t0 = time.perf_counter()
    with serve.QueryClient("127.0.0.1", port, encoding="binary",
                           timeout_s=120.0) as qc:
        while True:
            op = count % 5
            if op == 0:
                rep = qc.request("stats")
            elif op == 1:
                rep = qc.request("degrees")
            elif op == 2:
                rep = qc.request("top_k", k=10, by="out")
            elif op == 3:
                rep = qc.request("row", r=int(rng.integers(0, space)))
            else:
                rep = qc.request(
                    "get", r=int(rng.integers(0, space)),
                    c=int(rng.integers(0, space)),
                )
            assert rep.ok, rep.error
            count += 1
            if sent_done.is_set():
                rep = qc.request("degrees")
                count += 1
                if rep.ok and rep.view_records == n_records:
                    out["final_degrees"] = rep
                    break
                time.sleep(0.002)  # the covering view is one publish away
    dt = time.perf_counter() - t0
    out["queries"] = count
    out["qps"] = count / dt


def _serve_tcp(sess, r, c, v, batch: int, publish_every: int, space: int,
               with_queries: bool):
    src = serve.TCPSource(port=0, encoding="binary", linger=False)
    server = serve.D4MServer(
        sess, src,
        d4m.ServeConfig(max_latency_ms=1e9, publish_every=publish_every,
                        drain_timeout_s=600.0),
    ).start()
    out = {}
    sent_done = threading.Event()
    hammerer = None
    if with_queries:
        hammerer = threading.Thread(
            target=_hammer, args=(src.port, r.shape[0], space, sent_done, out),
            daemon=True,
        )
        hammerer.start()
    sent = serve.send_triples(
        "127.0.0.1", src.port, r, c, v,
        encoding="binary", chunk_records=4 * batch,
    )
    assert sent == r.shape[0]
    sent_done.set()
    if hammerer is not None:
        hammerer.join(timeout=600)
        assert not hammerer.is_alive(), "query hammer never saw the full view"
    assert server.join(timeout=600)
    report = server.report()
    assert report.drained and report.records_fed == r.shape[0]
    assert report.records_dropped == 0 and report.malformed == 0
    return report, out


def _bit_identical(sess: d4m.D4MStream, reply) -> bool:
    """The wire-served live-view degrees vs the drained snapshot reduction."""
    want_out, want_in = analytics.degrees(
        sess.snapshot(), cap=sess.plan.snapshot_cap, sr=sess.sr
    )

    def live(a):
        n = int(a.nnz)
        return np.asarray(a.rows)[:n], np.asarray(a.vals)[:n]

    for ids_key, vals_key, want in (
        ("out_ids", "out_vals", want_out), ("in_ids", "in_vals", want_in)
    ):
        ids, vals = live(want)
        if not np.array_equal(reply.arrays[ids_key], ids):
            return False
        got = np.asarray(reply.arrays[vals_key], np.float32)
        if not np.array_equal(got.view(np.uint32),
                              vals.astype(np.float32).view(np.uint32)):
            return False
    return True


def main(
    smoke: bool = False,
    k: int = 8,
    batches: int | None = None,
    batch: int | None = None,
    scale: int | None = None,
    publish_every: int | None = None,
):
    batches = batches if batches is not None else (60 if smoke else 400)
    batch = batch if batch is not None else (256 if smoke else 512)
    scale = scale if scale is not None else (14 if smoke else 18)
    # the last *periodic* publish must cover the whole stream (the final
    # drain view only appears after the query client disconnects)
    publish_every = publish_every if publish_every is not None else (
        6 if smoke else 10
    )
    assert batches % publish_every == 0
    top = int(batches * batch * 1.25)
    space = 1 << scale
    r, c, v = _workload(batches, batch, scale)
    params = {
        "k_per_device": k, "batches": batches, "batch": batch,
        "rmat_scale": scale, "publish_every": publish_every,
    }
    report = BenchmarkReport("query")

    sess = d4m.D4MStream(_config(k, batch, top))
    _warmup(sess, r, c, v, batch, space)
    only, _ = _serve_tcp(sess, r, c, v, batch, publish_every, space,
                         with_queries=False)
    print(
        f"query,ingest_only,k={k},rate={only.ingest_rate:,.0f}/s,"
        f"wall_s={only.wall_s:.3f},"
        f"views={only.telemetry['views_published']}", flush=True,
    )
    report.add(
        "ingest_only_rate", params=params,
        updates_per_sec=only.ingest_rate, wall_s=only.wall_s,
        views_published=int(only.telemetry["views_published"]),
    )

    sess = d4m.D4MStream(_config(k, batch, top))
    _warmup(sess, r, c, v, batch, space)
    mixed, out = _serve_tcp(sess, r, c, v, batch, publish_every, space,
                            with_queries=True)
    cost = 1.0 - mixed.ingest_rate / only.ingest_rate
    print(
        f"query,mixed,k={k},rate={mixed.ingest_rate:,.0f}/s,"
        f"wall_s={mixed.wall_s:.3f},qps={out['qps']:,.0f}/s,"
        f"queries={out['queries']},cost={cost:.3f}", flush=True,
    )
    report.add(
        "mixed_rate", params=params,
        updates_per_sec=mixed.ingest_rate, wall_s=mixed.wall_s,
        query_qps=out["qps"], queries_served=int(out["queries"]),
        ingest_cost=cost,
    )

    bit = _bit_identical(sess, out["final_degrees"])
    passed = bool(cost <= COST_CEILING and bit)
    print(
        f"verdict,query_cost,{passed},k={k},cost={cost:.3f},"
        f"ceiling={COST_CEILING},bit_identical={bit}"
    )
    report.add(
        "query_cost",
        params={**params, "ceiling": COST_CEILING},
        passed=passed,
        ingest_cost=float(cost),
        bit_identical=bool(bit),
        query_qps=float(out["qps"]),
    )
    report.write()
    return {"cost": cost, "qps": out["qps"], "bit_identical": bit}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--batches", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--scale", type=int, default=None)
    ap.add_argument("--publish-every", type=int, default=None)
    args = ap.parse_args()
    main(
        smoke=args.smoke,
        k=args.k,
        batches=args.batches,
        batch=args.batch,
        scale=args.scale,
        publish_every=args.publish_every,
    )
