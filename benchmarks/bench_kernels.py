"""Kernel microbenchmarks.

Pallas kernels are validated in interpret mode (CPU container; TPU is the
target), so wall-times here measure the *reference/XLA* path.  For each
kernel we report:
* ref-path time per call at several sizes (the production CPU fallback),
* interpret-mode kernel time (correctness-path cost, NOT a TPU number),
* the structural roofline of the kernel's TPU design: bytes moved per
  element and the VMEM working set implied by its BlockSpec tiling.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.reporting import BenchmarkReport
from repro.core import assoc, semiring
from repro.kernels.merge_add import ops as merge_ops
from repro.kernels.scatter_add import ops as scatter_ops
from repro.kernels.scatter_add.ref import scatter_add_ref
from repro.kernels.sort_dedup import ops as sort_ops


def _time(fn, *args, reps=5):
    fn(*args)  # compile
    jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def bench_merge(n: int, report: BenchmarkReport | None = None):
    rng = np.random.default_rng(0)
    a = assoc.from_triples(
        jnp.asarray(rng.integers(0, 10 * n, n), jnp.int32),
        jnp.asarray(rng.integers(0, 10 * n, n), jnp.int32),
        jnp.ones((n,), jnp.float32),
        cap=n,
    )
    b = assoc.from_triples(
        jnp.asarray(rng.integers(0, 10 * n, n), jnp.int32),
        jnp.asarray(rng.integers(0, 10 * n, n), jnp.int32),
        jnp.ones((n,), jnp.float32),
        cap=n,
    )
    ref_fn = jax.jit(lambda x, y: assoc.add(x, y, cap=2 * n))
    us_ref = _time(ref_fn, a, b)
    us_kern = _time(lambda x, y: merge_ops.merge_add(x, y, cap=2 * n), a, b)
    # TPU design structural stats: 4 lanes x 2n elements x 4 B through VMEM,
    # log2(2n) compare-exchange passes
    vmem_mb = 4 * 2 * n * 4 / 2**20
    print(
        f"merge_add,n={n},ref_us={us_ref:.0f},interp_us={us_kern:.0f},"
        f"vmem_mb={vmem_mb:.2f},elems_per_byte_hbm={2*n*12/(2*n*12):.1f}"
    )
    if report is not None:
        report.add(
            "merge_add",
            params={"n": n},
            updates_per_sec=2 * n / (us_ref / 1e6),
            wall_s=us_ref / 1e6,
            interp_us=us_kern,
            vmem_mb=vmem_mb,
        )


def bench_sort(n: int, report: BenchmarkReport | None = None):
    rng = np.random.default_rng(1)
    r = jnp.asarray(rng.integers(0, n, n), jnp.int32)
    c = jnp.asarray(rng.integers(0, n, n), jnp.int32)
    v = jnp.ones((n,), jnp.float32)
    us_ref = _time(jax.jit(lambda *t: assoc.from_triples(*t, cap=n)), r, c, v)
    us_kern = _time(lambda *t: sort_ops.from_triples(*t, cap=n), r, c, v)
    print(f"sort_dedup,n={n},ref_us={us_ref:.0f},interp_us={us_kern:.0f}")
    if report is not None:
        report.add(
            "sort_dedup",
            params={"n": n},
            updates_per_sec=n / (us_ref / 1e6),
            wall_s=us_ref / 1e6,
            interp_us=us_kern,
        )


def bench_scatter(v: int, d: int, k: int, report: BenchmarkReport | None = None):
    rng = np.random.default_rng(2)
    table = jnp.asarray(rng.normal(size=(v, d)), jnp.float32)
    ids = jnp.asarray(np.sort(rng.choice(v, k, replace=False)), jnp.int32)
    rows = jnp.asarray(rng.normal(size=(k, d)), jnp.float32)
    us_ref = _time(jax.jit(scatter_add_ref), ids, rows, table)
    # dense-equivalent: touch all V rows
    dense = jax.jit(lambda t, r: t + r)
    full = jnp.zeros_like(table)
    us_dense = _time(dense, table, full)
    print(
        f"scatter_add,V={v},d={d},k={k},sparse_us={us_ref:.0f},"
        f"dense_equiv_us={us_dense:.0f},bytes_ratio={v/k:.0f}x"
    )
    if report is not None:
        report.add(
            "scatter_add",
            params={"V": v, "d": d, "k": k},
            wall_s=us_ref / 1e6,
            dense_equiv_us=us_dense,
            bytes_ratio=v / k,
        )


def main(smoke: bool = False):
    report = BenchmarkReport("kernels")
    merge_sizes = (1 << 10,) if smoke else (1 << 10, 1 << 14, 1 << 17)
    sort_sizes = (1 << 10,) if smoke else (1 << 10, 1 << 14)
    for n in merge_sizes:
        bench_merge(n, report)
    for n in sort_sizes:
        bench_sort(n, report)
    bench_scatter(32_000, 512, 1024, report)
    if not smoke:
        bench_scatter(262_144, 512, 4096, report)
    report.write()


if __name__ == "__main__":
    main()
