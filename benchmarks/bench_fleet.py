"""Fleet benchmark: aggregate served rate vs worker-process count.

The paper's headline number is an *aggregate*: 1.9B updates/s is 34,000
independent D4M instances behind hierarchical routing, not one fast node.
This bench measures our fleet tier the same way — a hosts × K sweep where
each point spawns ``hosts`` worker subprocesses (each running the full
``repro.serve`` ingress stack over K packed instances), routes one R-MAT
stream across them with the two-level hash router, and reports

* **aggregate rate** — unique source records over the controller's wall
  clock (start-of-feed to last worker report), per fleet size;
* **per-worker rates** and the conservation verdict (every routed record
  delivered exactly once — ``FleetReport.conserved``);
* the **fleet_scaling verdict**: aggregate rate at N workers >=
  ``EFFICIENCY_FLOOR`` x N x single-worker rate, gated at the largest N
  the hardware can actually parallelize (``N <= usable_cores``) — on a
  many-core CI box that is the paper-shaped "N workers ~ N x one worker"
  claim; on a starved box (cores < every multi-host point) the verdict
  degrades to the N=1 leg so it never fails for lack of silicon, while
  the full rates-vs-hosts curve is still recorded for the trend gate.

Emits ``BENCH_fleet.json`` on the standard reporting schema, so the trend
gate tracks the rates and the verdict automatically.
"""
from __future__ import annotations

import argparse
import os
import tempfile

from benchmarks.reporting import BenchmarkReport
from repro import d4m, serve
from repro.fleet import FleetController

EFFICIENCY_FLOOR = 0.7  # aggregate(N) >= floor * min(N, cores) * aggregate(1)


def _usable_cores() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:  # pragma: no cover - non-Linux
        return os.cpu_count() or 1


def _config(k: int, batch: int, top: int) -> d4m.StreamConfig:
    return d4m.StreamConfig(
        cuts=(2 * batch, 16 * batch),
        top_capacity=top,
        batch_size=batch,
        instances_per_device=k,
        snapshot_cap=4 * top,
    )


def _worker_env(cache_dir: str) -> dict:
    """Pin each worker to one compute thread (the paper's one-core-per-
    instance shape) and share one compilation cache across the fleet so
    the N-th worker doesn't re-pay the first worker's compile."""
    return {
        "OMP_NUM_THREADS": "1",
        "OPENBLAS_NUM_THREADS": "1",
        "JAX_COMPILATION_CACHE_DIR": cache_dir,
        "JAX_PERSISTENT_CACHE_MIN_COMPILE_TIME_SECS": "0",
    }


def _run_fleet(
    n_workers: int,
    k: int,
    total: int,
    chunk: int,
    batch: int,
    scale: int,
    top: int,
    workdir: str,
    env: dict,
):
    src = serve.RMATSource(
        total, chunk_records=chunk, scale=scale, pregenerate=True
    )
    ctl = FleetController(
        _config(k, batch, top),
        n_workers=n_workers,
        workdir=os.path.join(workdir, f"h{n_workers}"),
        report_interval_s=0.5,
        env=env,
    )
    return ctl.run(src)


def main(
    smoke: bool = False,
    hosts_values=(1, 2, 4),
    k: int | None = None,
    total_records: int | None = None,
    chunk: int | None = None,
    batch: int | None = None,
    scale: int | None = None,
):
    k = k if k is not None else (2 if smoke else 4)
    total = total_records if total_records is not None else (
        24_000 if smoke else 400_000
    )
    chunk = chunk if chunk is not None else (1024 if smoke else 4096)
    batch = batch if batch is not None else (256 if smoke else 512)
    scale = scale if scale is not None else (14 if smoke else 18)
    top = int(total * 1.25)
    cores = _usable_cores()
    report = BenchmarkReport("fleet")
    rates: dict[int, float] = {}

    with tempfile.TemporaryDirectory(prefix="bench-fleet-") as workdir:
        env = _worker_env(os.path.join(workdir, "jax-cache"))
        # warmup fleet: populate the shared compilation cache so measured
        # legs time ingest, not XLA compiles
        _run_fleet(1, k, 4 * chunk, chunk, batch, scale, top, workdir, env)
        for hosts in hosts_values:
            rep = _run_fleet(
                hosts, k, total, chunk, batch, scale, top, workdir, env
            )
            if not rep.conserved:
                raise RuntimeError(
                    f"fleet hosts={hosts} lost records: routed "
                    f"{rep.records_in}, delivered {rep.records_delivered}"
                )
            rates[hosts] = rep.aggregate_rate
            params = {
                "hosts": hosts, "k_per_device": k, "total_records": total,
                "batch": batch, "rmat_scale": scale,
            }
            worker_rates = [
                float(w["ingest_rate"] or 0.0) for w in rep.per_worker
            ]
            print(
                f"fleet,aggregate,hosts={hosts},k={k},"
                f"rate={rep.aggregate_rate:,.0f}/s,wall_s={rep.wall_s:.3f},"
                f"conserved={rep.conserved},restarts={rep.restarts}",
                flush=True,
            )
            report.add(
                "fleet_rate", params=params,
                updates_per_sec=rep.aggregate_rate, wall_s=rep.wall_s,
                records_delivered=int(rep.records_delivered),
                conserved=bool(rep.conserved),
                restarts=int(rep.restarts),
                worker_rates=worker_rates,
                **rep.telemetry.serve_counters(),
            )

    # gate the largest fleet the hardware can actually run in parallel;
    # a 1-core box can only attest the N=1 leg (trivially true), but the
    # whole rates-vs-hosts curve still lands in the trend history
    parallelizable = [h for h in hosts_values if h <= cores]
    gate_hosts = max(parallelizable) if parallelizable else min(hosts_values)
    floor_rate = EFFICIENCY_FLOOR * gate_hosts * rates[min(hosts_values)]
    passed = rates[gate_hosts] >= floor_rate
    scaling = rates[gate_hosts] / max(rates[min(hosts_values)], 1e-9)
    print(
        f"verdict,fleet_scaling,{passed},hosts={gate_hosts},"
        f"scaling={scaling:.2f}x,cores={cores},"
        f"floor={EFFICIENCY_FLOOR}*{gate_hosts}",
        flush=True,
    )
    report.add(
        "fleet_scaling",
        params={
            "hosts": gate_hosts, "k_per_device": k,
            "floor": EFFICIENCY_FLOOR, "usable_cores": cores,
            "max_hosts_measured": int(max(hosts_values)),
        },
        passed=bool(passed),
        scaling={str(h): float(r) for h, r in rates.items()},
    )
    report.write()
    return rates


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--hosts", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--k", type=int, default=None)
    ap.add_argument("--total-records", type=int, default=None)
    ap.add_argument("--chunk", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--scale", type=int, default=None)
    args = ap.parse_args()
    main(
        smoke=args.smoke,
        hosts_values=tuple(args.hosts),
        k=args.k,
        total_records=args.total_records,
        chunk=args.chunk,
        batch=args.batch,
        scale=args.scale,
    )
