"""Paper Figs. 4 & 5: streaming update rate vs. number/spacing of cuts.

Single instance (single device), R-MAT power-law stream inserted in fixed
groups; we record the instantaneous rate per group and the cumulative rate,
for 0 / 2 / 4 / 8 cuts and for close vs. wide cut spacing (Fig. 3).

Expected qualitative reproduction (paper claims):
* 0 cuts: rate decays steadily as total entries grow;
* more cuts => higher and flatter instantaneous rate;
* rates collapse once the last cut is exceeded (tested by under-sizing).

Scale note: the paper streams 100 M edges on one core; default here is
laptop-scale (configurable with --edges).  Rates are reported per second of
wall time on this CPU — the *shape* of the curves, and the hierarchical vs.
flat ratio, are the reproduction targets (absolute updates/s on one CPU core
of this container are in the same 10^4-10^5 band as the paper's Fig. 4).
"""
from __future__ import annotations

import time
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

from benchmarks.reporting import BenchmarkReport
from repro import d4m
from repro.data import rmat


def run_stream(
    cuts: Sequence[int],
    total_edges: int,
    group_size: int,
    scale: int,
    top_capacity: int,
    seed: int = 0,
) -> Tuple[List[float], float, int]:
    """Returns (per-group instantaneous rates, cumulative rate, final nnz).

    Single instance on one device — the session resolves to the ``lax.cond``
    cascade (the seed's exact per-group program), so archived rate
    trajectories stay comparable across commits.
    """
    sess = d4m.D4MStream(d4m.StreamConfig(
        cuts=tuple(cuts), top_capacity=top_capacity, batch_size=group_size
    ))
    assert sess.kind == "single"
    rates = []
    # warmup/compile on one group (excluded from timing)
    s, d, v = next(rmat.edge_stream(seed + 999, group_size, group_size, scale))
    sess.update(s, d, v)
    jax.block_until_ready(sess.state)
    sess.reset()
    t_total = 0.0
    for s, d, v in rmat.edge_stream(seed, total_edges, group_size, scale):
        jax.block_until_ready((s, d, v))
        t0 = time.perf_counter()
        sess.update(s, d, v)
        jax.block_until_ready(sess.state)
        dt = time.perf_counter() - t0
        t_total += dt
        rates.append(group_size / dt)
    nnz = sess.nnz()
    assert not sess.overflowed(), "hierarchy overflow: sizing bug"
    return rates, total_edges / t_total, nnz


def cut_schedules(total_edges: int, group_size: int):
    """0/2/4/8-cut schedules mirroring Fig. 3's close vs. wide spacing."""
    e = total_edges
    g = group_size
    return {
        "0cut": (),
        "2cut_wide": (4 * g, e // 4),
        "4cut_close": (2 * g, 8 * g, 32 * g, 128 * g),
        "8cut_close": tuple(g * 2**i for i in range(1, 9)),
    }


def main(total_edges: int = 800_000, group_size: int = 5_000, scale: int = 18):
    report = BenchmarkReport("hier_update")
    rows = []
    top = int(total_edges * 1.4)
    for name, cuts in cut_schedules(total_edges, group_size).items():
        rates, cum, nnz = run_stream(cuts, total_edges, group_size, scale, top)
        n = len(rates)
        first, last = sum(rates[: n // 4]) / (n // 4), sum(rates[-n // 4 :]) / (n // 4)
        rows.append((name, cuts, cum, first, last, nnz))
        print(
            f"hier_update,{name},cuts={len(cuts)},cum_rate={cum:,.0f}/s,"
            f"first_quarter={first:,.0f}/s,last_quarter={last:,.0f}/s,nnz={nnz}",
            flush=True,
        )
        report.add(
            name,
            params={
                "cuts": list(cuts),
                "total_edges": total_edges,
                "group_size": group_size,
                "rmat_scale": scale,
            },
            updates_per_sec=cum,
            wall_s=total_edges / cum,
            first_quarter_rate=first,
            last_quarter_rate=last,
            nnz=int(nnz),
        )
    # paper-shape assertions (soft, printed as verdicts)
    byname = {r[0]: r for r in rows}
    flat_cum = byname["0cut"][2]
    best_cum = max(r[2] for r in rows)
    v1 = byname["8cut_close"][2] > flat_cum
    v2 = byname["0cut"][3] > byname["0cut"][4]  # 0-cut rate decays
    print(f"verdict,hier_beats_flat,{v1},ratio={best_cum/flat_cum:.2f}x")
    print(f"verdict,flat_rate_decays,{v2}")
    report.add("verdict_hier_beats_flat", passed=bool(v1), ratio=best_cum / flat_cum)
    report.add("verdict_flat_rate_decays", passed=bool(v2))
    report.write()
    return rows


if __name__ == "__main__":
    main()
