"""Paper Fig. 6: aggregate update rate vs. number of instances.

The paper's design is embarrassingly parallel: 34,000 *independent*
hierarchical-array instances (one per core) each ingesting its own stream,
with the aggregate rate = sum of instance rates — that independence is why
it scales linearly to 1.9 B updates/s.

Two instance axes are measured here:

* **D — devices** (``shard_map``, one instance per device): the seed's
  original sweep, N = 1, 2, 4, 8 host devices.  Identical program structure
  to the TPU deployment; the 512-device dry-run proves the same program
  lowers at pod scale.
* **K — packed instances per device** (``vmap``, new): the
  :class:`~repro.core.multistream.MultiStreamEngine` stacks K independent
  hierarchies per device and updates them in one fused branchless-cascade
  program, giving K x D total instances on a single host — the paper's
  instance-scaling curve without needing 34,000 cores.  Aggregate rate
  rises with K as per-dispatch overhead amortizes across the pack.

Besides the CSV lines, results are written to ``BENCH_scaling.json``
(see ``benchmarks/reporting.py``) so CI can archive the rate trajectory.

NOTE: run as a standalone script — it forces 8 host devices at import.
"""
from __future__ import annotations

import argparse
import os
import sys

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.reporting import BenchmarkReport
from repro import d4m
from repro.data import rmat


def make_session(
    k_per_device: int,
    n_dev: int,
    cuts,
    top_capacity: int,
    group_size: int,
    branchless: bool | None = True,
) -> d4m.D4MStream:
    """A mesh-engine session (forced even at D=1 so every sweep point runs
    the identical shard_map program structure — the seed's measurement)."""
    return d4m.D4MStream(d4m.StreamConfig(
        cuts=tuple(cuts),
        top_capacity=top_capacity,
        batch_size=group_size,
        instances_per_device=k_per_device,
        devices=n_dev,
        engine="mesh",
        branchless=branchless,
    ))


def run_packed(
    k_per_device: int,
    n_dev: int,
    groups: int = 20,
    group_size: int = 32,
    scale: int = 16,
    cuts=None,
    top_capacity: int | None = None,
    branchless: bool | None = True,
):
    """Aggregate updates/s with k_per_device x n_dev packed instances.

    The small default per-instance group keeps even K = 256 in the
    dispatch-amortization regime on a single shared CPU, so the measured
    K-curve reflects instance packing rather than compute saturation.
    ``branchless=True`` (default) makes every K point — including K = 1 —
    run the identical masked-cascade per-instance program, so the sweep
    isolates packing; pass ``None`` for the engine's auto (cond at K = 1)
    behavior.  Returns ``(aggregate_rate, wall_s, n_instances)``.
    """
    cuts = cuts if cuts is not None else (group_size, 4 * group_size)
    top = top_capacity if top_capacity is not None else int(groups * group_size * 1.25)
    sess = make_session(k_per_device, n_dev, cuts, top, group_size, branchless)
    n_inst = sess.n_instances
    # pre-generate the whole stream (host) so timing is pure update cost
    key = jax.random.PRNGKey(0)
    batches = []
    for _ in range(groups):
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, n_inst)
        s, d = jax.vmap(lambda k: rmat.rmat_edges(k, group_size, scale))(keys)
        batches.append(sess.shard_stream(s, d, jnp.ones((n_inst, group_size))))
    # warmup/compile (excluded from timing)
    sess.update(*batches[0])
    jax.block_until_ready(sess.state)
    sess.reset()
    t0 = time.perf_counter()
    for b in batches:
        sess.update(*b)
    jax.block_until_ready(sess.state)
    dt = time.perf_counter() - t0
    total_updates = n_inst * groups * group_size
    return total_updates / dt, dt, n_inst


def run_parallel(n_dev: int, groups: int = 20, group_size: int = 10_000, scale: int = 18):
    """Aggregate updates/s with n_dev one-per-device instances (K = 1).

    Keeps the seed sweep's exact configuration (cut schedule, top layer,
    and the lax.cond cascade program) so the archived device-axis
    trajectory stays comparable across commits.
    """
    rate, dt, _ = run_packed(
        1,
        n_dev,
        groups=groups,
        group_size=group_size,
        scale=scale,
        cuts=(2 * group_size, 16 * group_size),
        top_capacity=groups * group_size * 2,
        branchless=None,
    )
    return rate


def update_path_collectives(n_dev: int = None, k_per_device: int = 4) -> dict:
    """Compile the packed multi-instance update and count collectives in HLO.

    The paper's linear-scaling argument is structural: instances are
    independent, so the update path must contain ZERO cross-device
    collectives — we verify that property on the compiled program, now with
    K packed instances per device (the same check holds at 512 devices in
    the dry-run).  On this container all 'devices' share one CPU, so
    wall-clock aggregate rates CANNOT show device scaling; the structural
    check is the honest evidence.
    """
    import re

    n_dev = n_dev or len(jax.devices())
    sess = make_session(
        k_per_device, n_dev, (64,), top_capacity=4096, group_size=32,
        branchless=None,
    )
    n = sess.n_instances
    r = jnp.zeros((n, 32), jnp.int32)
    c = jnp.zeros((n, 32), jnp.int32)
    v = jnp.ones((n, 32))
    txt = (
        sess.raw_update.lower(sess.state, *sess.shard_stream(r, c, v))
        .compile()
        .as_text()
    )
    out = {}
    for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute"):
        out[k] = len(re.findall(rf"= [\w\[\],{{}}]+ {k}[(-]", txt))
    return out


def main(
    k_values=(1, 8, 64, 256),
    groups: int = 20,
    group_size: int = 32,
    scale: int = 16,
    device_sweep: bool = True,
):
    report = BenchmarkReport("scaling")
    max_dev = len(jax.devices())

    # -- D axis: one instance per device (the seed's sweep) ------------------
    if device_sweep:
        for n in [n for n in (1, 2, 4, 8) if n <= max_dev]:
            r = run_parallel(n)
            print(
                f"scaling,device_axis,n_instances={n},aggregate_rate={r:,.0f}/s,"
                f"per_instance={r/n:,.0f}/s", flush=True,
            )
            report.add(
                "device_scaling",
                params={"n_devices": n, "k_per_device": 1, "n_instances": n},
                updates_per_sec=r,
                per_instance_rate=r / n,
            )

    # -- K axis: packed instances per device (paper Fig. 6 shape) ------------
    k_rates = {}
    for k in k_values:
        rate, wall, n_inst = run_packed(
            k, max_dev, groups=groups, group_size=group_size, scale=scale
        )
        k_rates[k] = rate
        print(
            f"scaling,instance_axis,k_per_device={k},n_instances={n_inst},"
            f"aggregate_rate={rate:,.0f}/s,per_instance={rate/n_inst:,.0f}/s,"
            f"wall_s={wall:.3f}", flush=True,
        )
        report.add(
            "packed_scaling",
            params={
                "k_per_device": k,
                "n_devices": max_dev,
                "n_instances": n_inst,
                "groups": groups,
                "group_size": group_size,
                "rmat_scale": scale,
            },
            updates_per_sec=rate,
            wall_s=wall,
            per_instance_rate=rate / n_inst,
        )
    # On real hardware each instance has its own core and the curve is linear
    # (the paper's Fig. 6).  On this container every simulated device shares
    # one physical CPU, so the honest expectation is: aggregate rate RISES
    # with K until the CPU saturates, then flattens/dips.  The verdict checks
    # the rise (strictly increasing up to the best-K point, which must not be
    # the first sweep point); the saturation K is reported alongside.
    ks = sorted(k_rates)
    best_k = max(k_rates, key=k_rates.get)
    rising = [k for k in ks if k <= best_k]
    monotone_rise = len(rising) > 1 and all(
        k_rates[a] < k_rates[b] for a, b in zip(rising, rising[1:])
    )
    print(
        f"verdict,aggregate_rate_increases_with_k,{monotone_rise},"
        f"saturation_k={best_k},rates={k_rates}"
    )
    report.add(
        "verdict_rate_increases_with_k",
        params={"k_values": ks},
        passed=bool(monotone_rise),
        saturation_k=int(best_k),
        rates={str(k): k_rates[k] for k in ks},
    )

    # -- structural evidence: zero update-path collectives -------------------
    coll_k = 4
    colls = update_path_collectives(k_per_device=coll_k)
    total = sum(colls.values())
    print(f"verdict,update_path_collective_free,{total == 0},ops={colls}")
    report.add(
        "update_path_collectives",
        params={"k_per_device": coll_k, "n_devices": max_dev},
        passed=bool(total == 0),
        **colls,
    )
    print(
        "note,aggregate rates on this container share ONE physical CPU across "
        "simulated devices - scaling evidence is the collective-free update "
        "program (above) + the 512-chip dry-run lowering (EXPERIMENTS.md)"
    )
    per_inst = k_rates[best_k] / (best_k * max_dev)
    proj = per_inst * 34_000
    print(
        f"projection,34000_instances,{proj:,.0f}/s at this container's "
        f"per-instance rate,(paper: 1.9e9/s on 34,000 Xeon cores)"
    )
    report.add(
        "projection_34000_instances",
        params={"basis_k": best_k, "basis_devices": max_dev},
        updates_per_sec=proj,
    )
    report.write()
    return k_rates


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--k", type=int, nargs="+", default=[1, 8, 64, 256],
                    help="instances-per-device sweep points")
    ap.add_argument("--groups", type=int, default=20)
    ap.add_argument("--group-size", type=int, default=32)
    ap.add_argument("--scale", type=int, default=16)
    ap.add_argument("--no-device-sweep", action="store_true")
    args = ap.parse_args()
    main(
        k_values=tuple(args.k),
        groups=args.groups,
        group_size=args.group_size,
        scale=args.scale,
        device_sweep=not args.no_device_sweep,
    )
