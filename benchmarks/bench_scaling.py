"""Paper Fig. 6: aggregate update rate vs. number of instances.

The paper's design is embarrassingly parallel: 34,000 *independent*
hierarchical-array instances (one per core) each ingesting its own stream,
with the aggregate rate = sum of instance rates — that independence is why
it scales linearly to 1.9 B updates/s.

This benchmark reproduces the *shape* on CPU: ``shard_map`` over N host
devices (one instance per device, zero update-path collectives — identical
program structure to the TPU deployment), measuring aggregate rate at
N = 1, 2, 4, 8.  The 512-device multi-pod dry-run proves the same program
lowers at pod scale; the linear model fitted here, projected to the paper's
34,000 instances, is reported alongside (that projection is exactly the
paper's own argument, and our measured scaling efficiency quantifies how
safe it is).

NOTE: run as a standalone script — it forces 8 host devices at import.
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distributed, hierarchical
from repro.data import rmat


def run_parallel(n_dev: int, groups: int = 20, group_size: int = 10_000, scale: int = 18):
    """Aggregate updates/s with n_dev independent instances."""
    devs = jax.devices()[:n_dev]
    mesh = jax.sharding.Mesh(np.asarray(devs).reshape(n_dev), ("data",))
    cuts = (2 * group_size, 16 * group_size)
    ps = distributed.ParallelHierStream(
        mesh, cuts, top_capacity=groups * group_size * 2, batch_size=group_size
    )
    h = ps.init_state()
    # pre-generate the whole stream (host) so timing is pure update cost
    key = jax.random.PRNGKey(0)
    batches = []
    for g in range(groups):
        key, sub = jax.random.split(key)
        keys = jax.random.split(sub, n_dev)
        s, d = jax.vmap(lambda k: rmat.rmat_edges(k, group_size, scale))(keys)
        batches.append(ps.shard_stream(s, d, jnp.ones((n_dev, group_size))))
    # warmup
    h = ps.update(h, *batches[0])
    jax.block_until_ready(h)
    h = ps.init_state()
    t0 = time.perf_counter()
    for b in batches:
        h = ps.update(h, *b)
    jax.block_until_ready(h)
    dt = time.perf_counter() - t0
    total_updates = n_dev * groups * group_size
    return total_updates / dt


def update_path_collectives(n_dev: int = None) -> dict:
    """Compile the multi-instance update and count collectives in its HLO.

    The paper's linear-scaling argument is structural: instances are
    independent, so the update path must contain ZERO cross-device
    collectives — we verify that property on the compiled program (the same
    check holds at 512 devices in the dry-run).  On this container all
    'devices' share one CPU, so wall-clock aggregate rates CANNOT show
    scaling; the structural check is the honest evidence.
    """
    import re

    n_dev = n_dev or len(jax.devices())
    devs = jax.devices()[:n_dev]
    mesh = jax.sharding.Mesh(np.asarray(devs).reshape(n_dev), ("data",))
    ps = distributed.ParallelHierStream(mesh, (64,), top_capacity=4096, batch_size=32)
    h = ps.init_state()
    r = jnp.zeros((n_dev, 32), jnp.int32)
    c = jnp.zeros((n_dev, 32), jnp.int32)
    v = jnp.ones((n_dev, 32))
    txt = ps.update.lower(h, *ps.shard_stream(r, c, v)).compile().as_text()
    out = {}
    for k in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute"):
        out[k] = len(re.findall(rf"= [\w\[\],{{}}]+ {k}[(-]", txt))
    return out


def main():
    rates = {}
    max_dev = len(jax.devices())
    ns = [n for n in (1, 2, 4, 8) if n <= max_dev]
    for n in ns:
        r = run_parallel(n)
        rates[n] = r
        print(
            f"scaling,n_instances={n},aggregate_rate={r:,.0f}/s,"
            f"per_instance={r/n:,.0f}/s", flush=True,
        )
    colls = update_path_collectives()
    total = sum(colls.values())
    print(f"verdict,update_path_collective_free,{total == 0},ops={colls}")
    print(
        "note,aggregate rates on this container share ONE physical CPU across "
        "simulated devices - scaling evidence is the collective-free update "
        "program (above) + the 512-chip dry-run lowering (EXPERIMENTS.md)"
    )
    per_inst = rates[ns[0]]
    print(
        f"projection,34000_instances,{per_inst * 34_000:,.0f}/s at this "
        f"container's single-instance rate,(paper: 1.9e9/s on 34,000 Xeon cores)"
    )
    return rates


if __name__ == "__main__":
    main()
