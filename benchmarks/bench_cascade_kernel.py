"""Lane-skipping cascade kernel benchmark (BENCH_cascade_kernel.json).

Measures one packed update step — K instances, same cuts, same shapes — on
the three engines:

* ``branchless`` — the vmapped ``jnp.where`` cascade (``packed_update`` with
  ``branchless=True``): every layer merge executes every step, so per-step
  cost is Σ layer caps regardless of whether any cut fired;
* ``pallas`` — the ``hier_cascade`` kernel (interpret mode on CPU): layer
  merges are predicated per lane, so per-step cost tracks the lanes whose
  cuts actually fired;
* ``cond`` — the K=1 ``lax.cond`` reference path, for context.

Cascade frequency is swept two ways: by *key locality* (a small key space
keeps layer 1 under its cut forever — the 0%-cascade workload; a huge key
space forces a cascade every couple of steps) and by *cut schedule* (a tight
schedule cascades constantly).  The headline measurement is
``lane_skip_speedup``: pallas vs branchless per-step wall time on the
0%-cascade workload at equal K and cuts — the acceptance gate asserts >= 2x,
and ``passed`` feeds the CI regression gate's verdict tracking.

Interpret-mode caveat: pallas numbers here are the *correctness-path* cost
on CPU, not TPU numbers; the structural claim (cost tracking live lanes, not
Σ caps) is what the speedup demonstrates.
"""
from __future__ import annotations

import math
import time
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.reporting import BenchmarkReport
from repro.core import hierarchical, multistream
from repro.core.semiring import PLUS_TIMES
from repro.kernels.hier_cascade import ops as cascade_ops

BATCH = 256

# name -> (cuts, top_capacity, key_space); cascade rate is set by how fast
# distinct keys accumulate in layer 1 relative to c1
SCHEDULES = {
    # layer 1 can never exceed its cut: the pure fast path
    "0pct": ((512, 4096), 16384, 200),
    # fresh keys every batch: layer 1 fires every ~2-3 steps
    "hot": ((512, 4096), 16384, 1 << 30),
    # tight cut schedule: cascades on nearly every step at every layer
    "tight_cuts": ((64, 512), 16384, 1 << 30),
}


def _stream(seed, steps, k, key_space):
    # keys are (row, col) pairs: draw each coordinate from sqrt(key_space)
    # so the *pair* space is what bounds layer-1 occupancy
    side = max(1, math.isqrt(key_space))
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.integers(0, side, (steps, k, BATCH)), jnp.int32)
    c = jnp.asarray(rng.integers(0, side, (steps, k, BATCH)), jnp.int32)
    v = jnp.ones((steps, k, BATCH), jnp.float32)
    return r, c, v


def _time_engine(step, h0, R, C, V, warmup=2):
    """Thread state through `step` over the stream; per-step seconds."""
    h = h0
    for t in range(warmup):
        h = step(h, R[t], C[t], V[t])
    jax.block_until_ready(h.cascades)
    steps = R.shape[0] - warmup
    t0 = time.perf_counter()
    for t in range(warmup, R.shape[0]):
        h = step(h, R[t], C[t], V[t])
    jax.block_until_ready(h.cascades)
    return (time.perf_counter() - t0) / steps, h


def bench_point(k, name, steps, report):
    cuts, top, key_space = SCHEDULES[name]
    # stable per-schedule seed (hash() is salted per process: the gate must
    # compare runs measured on identical streams)
    R, C, V = _stream(zlib.crc32(name.encode()) % 1000, steps, k, key_space)

    # branchless vmapped cascade (forced even at K=1: same program per point)
    h_br = multistream.init_packed(k, cuts, top_capacity=top, batch_size=BATCH)
    br_step = jax.jit(
        lambda h, r, c, v: multistream.packed_update(
            h, r, c, v, cuts, PLUS_TIMES, branchless=True
        ),
        donate_argnums=(0,),
    )
    br_s, h_br = _time_engine(br_step, h_br, R, C, V)

    # lane-skipping pallas kernel
    h_pal, caps = cascade_ops.init_state(k, cuts, top, BATCH)
    pal_step = cascade_ops.build_step(cuts, caps, donate=True)
    pal_s, h_pal = _time_engine(pal_step, h_pal, R, C, V)

    casc = np.asarray(h_pal.cascades)[:, 1:].sum()
    rate = float(casc) / (steps * k)
    for engine, wall in (("branchless", br_s), ("pallas", pal_s)):
        print(
            f"cascade_step,k={k},schedule={name},engine={engine},"
            f"step_us={wall * 1e6:.0f},cascades_per_step={rate:.2f}",
            flush=True,
        )
        report.add(
            "cascade_step",
            params={"k": k, "schedule": name, "engine": engine},
            updates_per_sec=k * BATCH / wall,
            wall_s=wall,
            cascades_per_step=rate,
            sum_layer_caps=int(sum(caps)),
        )

    if k == 1:
        h_c = hierarchical.init(cuts, top_capacity=top, batch_size=BATCH)
        h_c = jax.tree.map(lambda x: x[None], h_c)
        cond_step = jax.jit(
            lambda h, r, c, v: multistream.packed_update(
                h, r, c, v, cuts, PLUS_TIMES
            ),
            donate_argnums=(0,),
        )
        cond_s, _ = _time_engine(cond_step, h_c, R, C, V)
        print(f"cascade_step,k=1,schedule={name},engine=cond,"
              f"step_us={cond_s * 1e6:.0f}", flush=True)
        report.add(
            "cascade_step",
            params={"k": 1, "schedule": name, "engine": "cond"},
            updates_per_sec=BATCH / cond_s,
            wall_s=cond_s,
            cascades_per_step=rate,
        )
    return br_s, pal_s, rate


def main(smoke: bool = False, k_values=None, steps: int | None = None):
    report = BenchmarkReport("cascade_kernel")
    ks = tuple(k_values) if k_values else ((1, 8) if smoke else (1, 8, 32))
    steps = steps or (8 if smoke else 16)
    names = ("0pct", "hot") if smoke else tuple(SCHEDULES)
    for k in ks:
        speedup = rate0 = None
        for name in names:
            br_s, pal_s, rate = bench_point(k, name, steps, report)
            if name == "0pct":
                speedup, rate0 = br_s / pal_s, rate
        if speedup is not None:
            # the headline claim is only meaningful on a true 0%-cascade stream
            ok = speedup >= 2.0 and rate0 == 0.0
            print(
                f"lane_skip_speedup,k={k},speedup={speedup:.1f}x,"
                f"cascades_per_step={rate0},passed={ok}", flush=True
            )
            report.add(
                "lane_skip_speedup",
                params={"k": k},
                speedup=float(speedup),
                cascades_per_step=float(rate0),
                passed=bool(ok),
            )
    report.write()


if __name__ == "__main__":
    main()
