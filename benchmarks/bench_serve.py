"""Serving benchmark: sustained end-to-end ingest rate vs. the raw engine.

The paper's rate is won at the *feeding* layer (arXiv:1902.00846,
arXiv:2001.06935): the device can only sustain its update rate if the
ingress path — parse, batch, hash-route, queue — keeps it busy.  This
bench measures exactly that overhead:

* **raw engine rate** — the lower-level ceiling: a timed ``update`` loop
  over pre-routed, pre-materialized ``[K, B]`` batches (no ingress path at
  all), same engine the session would pick;
* **served rate** — the same record workload pushed through the full
  ``repro.serve`` loop from a pre-generated R-MAT source (batching +
  routing + bounded queue + feed thread), timed start -> drain;
* **feed_efficiency** = served / raw, with the CI-gated verdict that the
  serve loop sustains >= 50% of the raw-engine rate at K=8 (the feed loop
  must not starve the device).  Values above 1.0 are real, not noise: the
  raw loop pays host-side conversion on its critical path, while the serve
  pipeline overlaps it with device execution on the reader thread — the
  double-buffering doing its job;
* an informational **socket rate** leg: the same path through a real
  loopback TCP socket (text wire format), where the parse cost joins the
  pipeline.

Emits ``BENCH_serve.json`` on the ``benchmarks/reporting.py`` schema, so
``regression_gate.py`` tracks both rates and the verdict automatically.
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.reporting import BenchmarkReport
from repro import d4m, serve
from repro.core.telemetry import TelemetrySnapshot

EFFICIENCY_FLOOR = 0.5  # served must reach this fraction of raw at K=8


def _config(k: int, batch: int, top: int) -> d4m.StreamConfig:
    return d4m.StreamConfig(
        cuts=(2 * batch, 16 * batch),
        top_capacity=top,
        batch_size=batch,
        instances_per_device=k,
        snapshot_cap=4 * top,
    )


def _workload(k: int, batches: int, batch: int, scale: int, seed: int = 0):
    """One flat record stream, plus its pre-routed per-batch host arrays
    (the raw-engine input) — both from the same R-MAT edges."""
    src = serve.RMATSource(
        batches * batch, chunk_records=batch, scale=scale, seed=seed,
        pregenerate=True,
    )
    rows, cols, vals = [], [], []
    for r, c, v in src.chunks():
        rows.append(r)
        cols.append(c)
        vals.append(v)
    flat = (np.concatenate(rows), np.concatenate(cols), np.concatenate(vals))
    routed = [
        serve.route_numpy(rows[t], cols[t], vals[t], k, batch)[:3]
        for t in range(batches)
    ]
    return flat, routed


def run_raw(sess: d4m.D4MStream, routed, batch: int) -> tuple[float, float]:
    """Timed update loop over pre-routed host batches: the engine ceiling.

    Feeds exactly what the serve loop's feed thread feeds (the same numpy
    arrays, the same ``jnp.asarray`` conversion, the same update step) with
    zero ingress machinery — so served/raw isolates the batching + routing
    + queue + thread overhead and nothing else.
    """
    squeeze = sess.kind == "single"

    def step(b):
        r, c, v = b
        if squeeze:
            r, c, v = r[0], c[0], v[0]
        sess.update(jnp.asarray(r), jnp.asarray(c), jnp.asarray(v))

    step(routed[0])  # warmup/compile
    jax.block_until_ready(sess.state)
    sess.reset()
    t0 = time.perf_counter()
    for b in routed:
        step(b)
    jax.block_until_ready(sess.state)
    dt = time.perf_counter() - t0
    return len(routed) * batch / dt, dt


def run_served(sess: d4m.D4MStream, flat, batch: int):
    """Timed full serve loop from a pre-materialized source."""
    r, c, v = flat
    # warmup/compile through the same path, then reset state (compiled fns
    # and the live threadless router are cheap to rebuild)
    warm = sess.serve(
        serve.ArraySource(r[: 2 * batch], c[: 2 * batch], v[: 2 * batch],
                          chunk_records=batch),
        max_latency_ms=1e9,
    )
    assert warm.drained
    sess.reset()
    report = sess.serve(
        serve.ArraySource(r, c, v, chunk_records=batch), max_latency_ms=1e9
    )
    assert report.drained and report.records_dropped == 0
    return report.ingest_rate, report.wall_s, report.telemetry


def run_socket(sess: d4m.D4MStream, flat, batch: int) -> tuple[float, float]:
    """Same loop through a real loopback TCP socket (text wire format)."""
    r, c, v = flat
    sess.reset()
    src = serve.TCPSource(port=0).start()
    sender = threading.Thread(
        target=serve.send_triples,
        args=("127.0.0.1", src.port, r, c, v),
        kwargs={"chunk_records": 4 * batch},
    )
    sender.start()
    report = sess.serve(src, max_latency_ms=1e9)
    sender.join(timeout=60)
    assert report.drained
    return report.ingest_rate, report.wall_s


def main(
    smoke: bool = False,
    k_values=(1, 8),
    batches: int | None = None,
    batch: int | None = None,
    scale: int | None = None,
):
    batches = batches if batches is not None else (60 if smoke else 400)
    batch = batch if batch is not None else (256 if smoke else 512)
    scale = scale if scale is not None else (14 if smoke else 18)
    top = int(batches * batch * 1.25)
    report = BenchmarkReport("serve")
    efficiency = {}
    served_tels = []
    for k in k_values:
        flat, routed = _workload(k, batches, batch, scale)
        params = {
            "k_per_device": k, "batches": batches, "batch": batch,
            "rmat_scale": scale,
        }
        sess = d4m.D4MStream(_config(k, batch, top))
        raw_rate, raw_wall = run_raw(sess, routed, batch)
        print(
            f"serve,raw_engine,k={k},rate={raw_rate:,.0f}/s,"
            f"wall_s={raw_wall:.3f}", flush=True,
        )
        report.add("raw_engine_rate", params=params,
                   updates_per_sec=raw_rate, wall_s=raw_wall)

        sess = d4m.D4MStream(_config(k, batch, top))
        served_rate, served_wall, tel = run_served(sess, flat, batch)
        efficiency[k] = served_rate / raw_rate
        print(
            f"serve,served,k={k},rate={served_rate:,.0f}/s,"
            f"wall_s={served_wall:.3f},efficiency={efficiency[k]:.2f},"
            f"blocked={tel.blocked_events}", flush=True,
        )
        served_tels.append(tel)
        report.add(
            "served_rate", params=params,
            updates_per_sec=served_rate, wall_s=served_wall,
            efficiency=efficiency[k],
            **tel.serve_counters(),
        )

        sock_rate, sock_wall = run_socket(sess, flat, batch)
        print(
            f"serve,socket,k={k},rate={sock_rate:,.0f}/s,"
            f"wall_s={sock_wall:.3f}", flush=True,
        )
        report.add("socket_rate", params=params,
                   updates_per_sec=sock_rate, wall_s=sock_wall)

    # cross-leg totals via the typed merge (was: ad-hoc per-key dict sums)
    totals = TelemetrySnapshot.merge(served_tels)
    report.add(
        "served_totals",
        params={"k_values": [int(k) for k in k_values]},
        **totals.serve_counters(),
    )

    gate_k = max(k_values)
    passed = efficiency[gate_k] >= EFFICIENCY_FLOOR
    print(
        f"verdict,feed_efficiency,{passed},k={gate_k},"
        f"efficiency={efficiency[gate_k]:.2f},floor={EFFICIENCY_FLOOR}"
    )
    report.add(
        "feed_efficiency",
        params={"k_per_device": gate_k, "floor": EFFICIENCY_FLOOR},
        passed=bool(passed),
        efficiency={str(k): float(e) for k, e in efficiency.items()},
    )
    report.write()
    return efficiency


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--k", type=int, nargs="+", default=[1, 8])
    ap.add_argument("--batches", type=int, default=None)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--scale", type=int, default=None)
    args = ap.parse_args()
    main(
        smoke=args.smoke,
        k_values=tuple(args.k),
        batches=args.batches,
        batch=args.batch,
        scale=args.scale,
    )
