"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,...`` CSV lines AND writes ``BENCH_<section>.json`` structured
results (schema: ``benchmarks/reporting.py``) to ``--json-dir``; sections:
  hier            — paper Figs. 4/5 (update rate vs cuts, instantaneous decay)
  scaling         — paper Fig. 6 shape: aggregate rate vs instances, on two
                    axes — D devices (run standalone or with
                    XLA_FLAGS=--xla_force_host_platform_device_count=8) and
                    K vmap-packed instances per device (K ∈ {1, 8, 64, 256})
  kernels         — Pallas kernel ref/interp microbenches + TPU design stats
  embed           — LM integration: hierarchical sparse embedding-grad traffic
  cascade_kernel  — lane-skipping hier_cascade kernel vs the branchless
                    cascade: per-step cost vs cascade frequency x K
  serve           — streaming ingress loop (repro.serve): sustained served
                    rate vs raw-engine rate at K ∈ {1, 8}, with the
                    feed_efficiency (>= 50% at K=8) verdict + a loopback
                    TCP socket leg

Select sections with ``--sections hier,scaling`` (comma-separated; CI smoke
uses this to run only the cheap sections) or the legacy single ``--section``.

Scale: laptop-size defaults (--full restores paper-scale streams; --smoke
shrinks everything for CI).
"""
import argparse
import os
import sys

SECTIONS = ("hier", "kernels", "embed", "scaling", "cascade_kernel", "serve")


def parse_sections(args: argparse.Namespace) -> set:
    if args.sections:
        chosen = {s.strip() for s in args.sections.split(",") if s.strip()}
        bad = chosen - set(SECTIONS)
        if bad:
            raise SystemExit(
                f"unknown section(s) {sorted(bad)}; known: {list(SECTIONS)}"
            )
        return chosen
    if args.section == "all":
        return set(SECTIONS)
    return {args.section}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all",
                    choices=["all", *SECTIONS])
    ap.add_argument("--sections", default=None,
                    help="comma-separated subset of sections to run "
                         f"(overrides --section): {','.join(SECTIONS)}")
    ap.add_argument("--full", action="store_true", help="paper-scale streams")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-size streams (fast, still exercises every path)")
    ap.add_argument("--json-dir", default=None,
                    help="directory for BENCH_<section>.json (default: cwd)")
    args = ap.parse_args()
    if args.json_dir:
        os.environ["BENCH_JSON_DIR"] = args.json_dir
    run = parse_sections(args)

    if "hier" in run:
        from benchmarks import bench_hier_update
        if args.full:
            bench_hier_update.main(total_edges=100_000_000, group_size=100_000, scale=26)
        elif args.smoke:
            bench_hier_update.main(total_edges=80_000, group_size=2_000, scale=14)
        else:
            bench_hier_update.main()
    if "kernels" in run:
        from benchmarks import bench_kernels
        bench_kernels.main(smoke=args.smoke)
    if "embed" in run:
        from benchmarks import bench_embed_grad
        bench_embed_grad.main(smoke=args.smoke)
    if "scaling" in run:
        from benchmarks import bench_scaling
        if args.smoke:
            bench_scaling.main(k_values=(1, 8), groups=5, device_sweep=False)
        else:
            bench_scaling.main()
    if "cascade_kernel" in run:
        from benchmarks import bench_cascade_kernel
        bench_cascade_kernel.main(smoke=args.smoke)
    if "serve" in run:
        from benchmarks import bench_serve
        bench_serve.main(smoke=args.smoke)


if __name__ == "__main__":
    main()
