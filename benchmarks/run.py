"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,...`` CSV lines; sections:
  hier_update   — paper Figs. 4/5 (update rate vs cuts, instantaneous decay)
  scaling       — paper Fig. 6 shape (aggregate rate vs instances; run
                  standalone with XLA_FLAGS=--xla_force_host_platform_device_count=8
                  for the multi-instance points; in-process fallback = 1 instance)
  kernels       — Pallas kernel ref/interp microbenches + TPU design stats
  embed_grad    — LM integration: hierarchical sparse embedding-grad traffic

Scale: laptop-size defaults (--full restores paper-scale streams).
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all",
                    choices=["all", "hier", "kernels", "embed", "scaling"])
    ap.add_argument("--full", action="store_true", help="paper-scale streams")
    args = ap.parse_args()

    if args.section in ("all", "hier"):
        from benchmarks import bench_hier_update
        if args.full:
            bench_hier_update.main(total_edges=100_000_000, group_size=100_000, scale=26)
        else:
            bench_hier_update.main()
    if args.section in ("all", "kernels"):
        from benchmarks import bench_kernels
        bench_kernels.main()
    if args.section in ("all", "embed"):
        from benchmarks import bench_embed_grad
        bench_embed_grad.main()
    if args.section in ("all", "scaling"):
        from benchmarks import bench_scaling
        bench_scaling.main()


if __name__ == "__main__":
    main()
