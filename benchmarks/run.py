"""Benchmark orchestrator — config-driven experiments over paper sections.

The canonical entry point is an experiment config::

    python -m benchmarks.run --experiment benchmarks/experiments/ci-smoke.json

which loads a :class:`repro.bench.ExperimentSpec` (sections × engine × K ×
D × source from one JSON/TOML file; ``matrix`` axes cross-multiply into
legs) and executes every leg, writing ``BENCH_<section>.json`` per section
(schema: ``repro.bench.reporting``) plus ``name,...`` CSV lines.  Sections:
  hier            — paper Figs. 4/5 (update rate vs cuts, instantaneous decay)
  scaling         — paper Fig. 6 shape: aggregate rate vs instances, on two
                    axes — D devices (run standalone or with
                    XLA_FLAGS=--xla_force_host_platform_device_count=8) and
                    K vmap-packed instances per device (K ∈ {1, 8, 64, 256})
  kernels         — Pallas kernel ref/interp microbenches + TPU design stats
  embed           — LM integration: hierarchical sparse embedding-grad traffic
  cascade_kernel  — lane-skipping hier_cascade kernel vs the branchless
                    cascade: per-step cost vs cascade frequency x K
  serve           — streaming ingress loop (repro.serve): sustained served
                    rate vs raw-engine rate at K ∈ {1, 8}, with the
                    feed_efficiency (>= 50% at K=8) verdict + a loopback
                    TCP socket leg
  fleet           — multi-process scale-out (repro.fleet): aggregate served
                    rate vs worker count (hosts × K sweep over subprocess
                    workers behind the two-level hash router), with the
                    fleet_scaling (>= 0.7 × min(N, cores) × single-worker
                    rate) verdict and record-conservation checks

The legacy flags (``--section hier``, ``--sections hier,scaling``,
``--smoke``, ``--full``) still work as a deprecation shim: they synthesize
the equivalent spec via ``ExperimentSpec.from_legacy`` with the exact
historical parameter values, so archived rate trajectories stay comparable.
Prefer a committed config file for anything you run twice.
"""
import argparse
import os
import sys

from repro.bench.experiments import (  # noqa: F401  (SECTIONS re-exported)
    SECTIONS,
    ExperimentError,
    ExperimentSpec,
    run_spec,
)


def parse_sections(args: argparse.Namespace) -> set:
    """Legacy section selection (kept for callers importing this helper)."""
    if args.sections:
        chosen = {s.strip() for s in args.sections.split(",") if s.strip()}
        bad = chosen - set(SECTIONS)
        if bad:
            raise SystemExit(
                f"unknown section(s) {sorted(bad)}; known: {list(SECTIONS)}"
            )
        return chosen
    if args.section == "all":
        return set(SECTIONS)
    return {args.section}


def build_spec(args: argparse.Namespace) -> ExperimentSpec:
    if args.experiment:
        if args.sections or args.section != "all" or args.smoke or args.full:
            raise SystemExit(
                "--experiment replaces --section/--sections/--smoke/--full; "
                "put the legs in the config file instead"
            )
        return ExperimentSpec.from_file(args.experiment)
    if args.sections or args.section != "all" or args.smoke or args.full:
        print(
            "run,deprecated,--section/--sections/--smoke/--full are legacy; "
            "use --experiment <config.json> (see benchmarks/experiments/)",
            file=sys.stderr,
        )
    # stable leg order: the canonical SECTIONS order, not the set's
    chosen = parse_sections(args)
    ordered = [s for s in SECTIONS if s in chosen]
    return ExperimentSpec.from_legacy(
        ordered, smoke=args.smoke, full=args.full, json_dir=args.json_dir
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--experiment", default=None, metavar="CONFIG",
                    help="experiment config (JSON or TOML) defining the legs "
                         "to run; replaces the legacy section flags")
    ap.add_argument("--section", default="all",
                    choices=["all", *SECTIONS])
    ap.add_argument("--sections", default=None,
                    help="comma-separated subset of sections to run "
                         f"(overrides --section): {','.join(SECTIONS)}")
    ap.add_argument("--full", action="store_true", help="paper-scale streams")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-size streams (fast, still exercises every path)")
    ap.add_argument("--json-dir", default=None,
                    help="directory for BENCH_<section>.json (default: cwd)")
    args = ap.parse_args()
    if args.json_dir:
        os.environ["BENCH_JSON_DIR"] = args.json_dir
    try:
        spec = build_spec(args)
    except ExperimentError as e:
        raise SystemExit(str(e))
    run_spec(spec, json_dir=args.json_dir)


if __name__ == "__main__":
    main()
