"""Benchmark orchestrator — one section per paper table/figure.

Prints ``name,...`` CSV lines AND writes ``BENCH_<section>.json`` structured
results (schema: ``benchmarks/reporting.py``) to ``--json-dir``; sections:
  hier_update   — paper Figs. 4/5 (update rate vs cuts, instantaneous decay)
  scaling       — paper Fig. 6 shape: aggregate rate vs instances, on two
                  axes — D devices (run standalone or with
                  XLA_FLAGS=--xla_force_host_platform_device_count=8) and
                  K vmap-packed instances per device (K ∈ {1, 8, 64, 256})
  kernels       — Pallas kernel ref/interp microbenches + TPU design stats
  embed_grad    — LM integration: hierarchical sparse embedding-grad traffic

Scale: laptop-size defaults (--full restores paper-scale streams; --smoke
shrinks everything for CI).
"""
import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--section", default="all",
                    choices=["all", "hier", "kernels", "embed", "scaling"])
    ap.add_argument("--full", action="store_true", help="paper-scale streams")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-size streams (fast, still exercises every path)")
    ap.add_argument("--json-dir", default=None,
                    help="directory for BENCH_<section>.json (default: cwd)")
    args = ap.parse_args()
    if args.json_dir:
        os.environ["BENCH_JSON_DIR"] = args.json_dir

    if args.section in ("all", "hier"):
        from benchmarks import bench_hier_update
        if args.full:
            bench_hier_update.main(total_edges=100_000_000, group_size=100_000, scale=26)
        elif args.smoke:
            bench_hier_update.main(total_edges=80_000, group_size=2_000, scale=14)
        else:
            bench_hier_update.main()
    if args.section in ("all", "kernels"):
        from benchmarks import bench_kernels
        bench_kernels.main(smoke=args.smoke)
    if args.section in ("all", "embed"):
        from benchmarks import bench_embed_grad
        bench_embed_grad.main(smoke=args.smoke)
    if args.section in ("all", "scaling"):
        from benchmarks import bench_scaling
        if args.smoke:
            bench_scaling.main(k_values=(1, 8), groups=5, device_sweep=False)
        else:
            bench_scaling.main()


if __name__ == "__main__":
    main()
