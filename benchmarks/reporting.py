"""Deprecation shim — the artifact writer lives in :mod:`repro.bench.reporting`.

Kept so ``from benchmarks.reporting import BenchmarkReport`` (every
``bench_*`` module, plus any external automation) keeps working; new code
should import from ``repro.bench``.
"""
from repro.bench.reporting import (  # noqa: F401
    SCHEMA_VERSION,
    BenchmarkReport,
    git_branch,
    git_commit_hash,
)

__all__ = ["SCHEMA_VERSION", "BenchmarkReport", "git_branch", "git_commit_hash"]
