"""Deprecation shim — the regression gate lives in :mod:`repro.bench.gate`.

``python -m benchmarks.regression_gate --baseline <dir> --fresh <dir>``
keeps its exact legacy contract (single-baseline diff, same CSV lines, same
exit codes): the baseline directory is folded in as a one-entry history, so
the legacy single-sample comparison is just the trend gate with a window of
size 1.  New code (and CI) should run ``python -m repro.bench.gate`` with
``--history benchmarks/history/perf_history.jsonl`` to gate against the
rolling-window trend instead of one noisy previous run.
"""
import sys

from repro.bench.gate import (  # noqa: F401
    GateFinding,
    GateResult,
    gate_run,
    load_measurements,
    main,
)

__all__ = ["GateFinding", "GateResult", "gate_run", "load_measurements", "main"]

if __name__ == "__main__":
    sys.exit(main())
