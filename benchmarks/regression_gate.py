"""Benchmark regression gate: diff fresh ``BENCH_<section>.json`` artifacts
against a baseline run (the previous CI artifact, per the ROADMAP convention).

For every measurement present in BOTH runs (matched by section + name +
params) that carries an ``updates_per_sec`` rate:

* drop  > ``--fail`` (default 30%)  -> exit 1 (regression gate trips)
* drop  > ``--warn`` (default 10%)  -> warning line, exit 0
* otherwise                         -> ok line

Boolean ``passed`` verdicts regressing from true to false also trip the
gate (a shape/structure property broke, not just a rate).

A missing/empty/unreadable baseline exits 0 with a ``baseline-established``
line — the first run on a branch, or an expired artifact, must not block CI;
the fresh artifacts it uploads become the next run's baseline.  Sections are
matched purely by the ``reporting.py`` schema (section + name + params), so
any new ``BENCH_<section>.json`` a benchmark emits is covered automatically
— no gate changes needed per benchmark (asserted by
``tests/benchmarks/test_regression_gate.py``).

Usage:
  python -m benchmarks.regression_gate --baseline bench-baseline \
      --fresh bench-artifacts [--warn 0.10] [--fail 0.30]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, Tuple


def _key(section: str, m: dict) -> Tuple:
    params = tuple(sorted((k, repr(v)) for k, v in (m.get("params") or {}).items()))
    return (section, m.get("name"), params)


def load_measurements(dir_path: str) -> Dict[Tuple, dict]:
    out: Dict[Tuple, dict] = {}
    for path in sorted(glob.glob(os.path.join(dir_path, "BENCH_*.json"))):
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"gate,unreadable,{path},{e}")
            continue
        section = payload.get("section", os.path.basename(path))
        for m in payload.get("measurements", []):
            out[_key(section, m)] = m
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="directory with the previous run's BENCH_*.json")
    ap.add_argument("--fresh", required=True,
                    help="directory with this run's BENCH_*.json")
    ap.add_argument("--warn", type=float, default=0.10,
                    help="rate-drop fraction that warns (default 0.10)")
    ap.add_argument("--fail", type=float, default=0.30,
                    help="rate-drop fraction that fails (default 0.30)")
    args = ap.parse_args(argv)

    fresh = load_measurements(args.fresh)
    if not fresh:
        print(f"gate,error,no fresh BENCH_*.json under {args.fresh}")
        return 1
    baseline = load_measurements(args.baseline) if os.path.isdir(args.baseline) else {}
    if not baseline:
        # first run on a branch / expired artifact: a clean pass, and this
        # run's uploaded artifacts become the baseline for the next one
        print(
            f"gate,baseline-established,{len(fresh)} fresh measurement(s), "
            f"no baseline under {args.baseline} - nothing to compare"
        )
        print("gate,verdict,PASS")
        return 0

    failures, warnings_, compared = [], [], 0
    for key, fm in sorted(fresh.items()):
        bm = baseline.get(key)
        if bm is None:
            continue
        params = fm.get("params") or {}
        short = ",".join(f"{k}={v}" for k, v in sorted(params.items())[:3])
        label = f"{key[0]}/{key[1]}" + (f"[{short}]" if short else "")
        if "updates_per_sec" in fm and "updates_per_sec" in bm:
            compared += 1
            base, now = float(bm["updates_per_sec"]), float(fm["updates_per_sec"])
            if base <= 0:
                continue
            drop = (base - now) / base
            tag = "ok"
            if drop > args.fail:
                tag = "FAIL"
                failures.append(label)
            elif drop > args.warn:
                tag = "WARN"
                warnings_.append(label)
            print(
                f"gate,{tag},{label},baseline={base:,.0f}/s,fresh={now:,.0f}/s,"
                f"drop={drop:+.1%}"
            )
        elif "passed" in fm and "passed" in bm:
            compared += 1
            if bool(bm["passed"]) and not bool(fm["passed"]):
                failures.append(label)
                print(f"gate,FAIL,{label},verdict regressed true -> false")
            else:
                print(f"gate,ok,{label},verdict={fm['passed']}")

    print(
        f"gate,summary,compared={compared},warned={len(warnings_)},"
        f"failed={len(failures)}"
    )
    if failures:
        print(f"gate,verdict,FAIL,regressions: {', '.join(failures)}")
        return 1
    print("gate,verdict,PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
